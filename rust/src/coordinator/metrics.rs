//! Per-round metrics and the training log every experiment consumes.

use std::io::Write as _;

use super::link::ParticipationStats;

/// One synchronous round's record.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub iter: usize,
    /// Test accuracy (NaN when not evaluated this round).
    pub test_accuracy: f64,
    /// Training loss averaged over the devices' shards (NaN when skipped).
    pub train_loss: f64,
    /// ‖ĝ‖ of the PS's reconstructed gradient.
    pub grad_norm: f64,
    /// Digital: largest *actual* per-device payload this round — the
    /// capacity budget R_t bounds it (asserted in `DigitalLink`), but an
    /// undershooting compressor reports what it really spent. 0 for analog.
    pub bits_per_device: f64,
    /// Power P_t allocated this round.
    pub p_t: f64,
    /// AMP iterations used (0 for digital).
    pub amp_iterations: usize,
    /// Mean ‖Δ_m‖ across devices (error-accumulator magnitude).
    pub accumulator_norm: f64,
    /// Wall-clock seconds for the round.
    pub round_secs: f64,
    /// Participation counts for links that model a variable transmitting
    /// set. `None` means "not modeled by this scheme" — deliberately
    /// distinct from `Some` with zero transmitting devices (an all-silent
    /// round). CSV serializes `None` as NaN, never 0.
    pub participation: Option<ParticipationStats>,
    /// Root-mean-square replica disagreement for decentralized links
    /// (√((1/M)Σ‖θ_i − θ̄‖²) after the round). `None` for PS-centric
    /// schemes — one global model has no disagreement to measure, which is
    /// not the same as a measured 0 (exact consensus). CSV serializes
    /// `None` as NaN.
    pub consensus_distance: Option<f64>,
}

/// Full log of a run plus final power audit.
#[derive(Clone, Debug)]
pub struct TrainLog {
    pub label: String,
    pub records: Vec<RoundRecord>,
    /// Per-device average transmit power measured over the run.
    pub measured_avg_power: Vec<f64>,
    pub pbar: f64,
    /// Final test accuracy (last evaluated value).
    pub final_accuracy: f64,
    pub total_secs: f64,
}

impl TrainLog {
    /// Accuracy series as (iteration, accuracy) for evaluated rounds only.
    pub fn accuracy_series(&self) -> Vec<(usize, f64)> {
        self.records
            .iter()
            .filter(|r| !r.test_accuracy.is_nan())
            .map(|r| (r.iter, r.test_accuracy))
            .collect()
    }

    /// Best accuracy reached.
    pub fn best_accuracy(&self) -> f64 {
        self.accuracy_series()
            .iter()
            .map(|&(_, a)| a)
            .fold(0.0, f64::max)
    }

    /// Eq. 6 audit: every device's measured average power within P̄.
    pub fn power_constraint_ok(&self, tol: f64) -> bool {
        self.measured_avg_power
            .iter()
            .all(|&p| p <= self.pbar * (1.0 + tol))
    }

    /// The worst (largest) measured per-device average power — the side
    /// of the Eq. 6 audit that actually binds. NaN when unmeasured.
    pub fn max_avg_power(&self) -> f64 {
        self.measured_avg_power
            .iter()
            .copied()
            .fold(f64::NAN, f64::max)
    }

    /// Eq. 6 audit headroom: the fraction of the power budget the
    /// worst device left unused, `1 − max_avg_power / P̄`. NaN when
    /// unmeasured or the budget is non-positive.
    pub fn power_headroom(&self) -> f64 {
        if self.pbar > 0.0 {
            1.0 - self.max_avg_power() / self.pbar
        } else {
            f64::NAN
        }
    }

    /// Write the full per-round series as CSV. The participation columns
    /// are NaN for schemes that do not model participation — an honest
    /// "absent", never conflated with a measured 0.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut w = crate::util::csv::CsvWriter::create(
            path,
            &[
                "iter",
                "test_accuracy",
                "train_loss",
                "grad_norm",
                "bits_per_device",
                "p_t",
                "amp_iterations",
                "accumulator_norm",
                "round_secs",
                "participating",
                "dropped_stragglers",
                "consensus_distance",
            ],
        )?;
        for r in &self.records {
            let (participating, stragglers) = match r.participation {
                Some(p) => (p.transmitting as f64, p.dropped_stragglers as f64),
                None => (f64::NAN, f64::NAN),
            };
            w.write_row(&[
                r.iter as f64,
                r.test_accuracy,
                r.train_loss,
                r.grad_norm,
                r.bits_per_device,
                r.p_t,
                r.amp_iterations as f64,
                r.accumulator_norm,
                r.round_secs,
                participating,
                stragglers,
                r.consensus_distance.unwrap_or(f64::NAN),
            ])?;
        }
        w.flush()
    }

    /// Human-oriented progress line.
    pub fn print_progress(&self, r: &RoundRecord) {
        let acc = if r.test_accuracy.is_nan() {
            "  --  ".to_string()
        } else {
            format!("{:.4}", r.test_accuracy)
        };
        let mut line = format!(
            "[{}] t={:<4} acc={} loss={:.4} ‖ĝ‖={:.4}",
            self.label, r.iter, acc, r.train_loss, r.grad_norm
        );
        if r.bits_per_device > 0.0 {
            line.push_str(&format!(" bits={:.0}", r.bits_per_device));
        }
        if r.amp_iterations > 0 {
            line.push_str(&format!(" amp={}", r.amp_iterations));
        }
        if let Some(p) = r.participation {
            line.push_str(&format!(" tx={}/{}", p.transmitting, p.total()));
            if p.dropped_stragglers > 0 {
                line.push_str(&format!(" straggled={}", p.dropped_stragglers));
            }
        }
        if let Some(c) = r.consensus_distance {
            line.push_str(&format!(" cons={c:.4}"));
        }
        println!("{line}");
        let _ = std::io::stdout().flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(iter: usize, acc: f64) -> RoundRecord {
        RoundRecord {
            iter,
            test_accuracy: acc,
            train_loss: 1.0,
            grad_norm: 0.5,
            bits_per_device: 0.0,
            p_t: 100.0,
            amp_iterations: 3,
            accumulator_norm: 0.0,
            round_secs: 0.01,
            participation: None,
            consensus_distance: None,
        }
    }

    #[test]
    fn series_skips_unevaluated() {
        let log = TrainLog {
            label: "t".into(),
            records: vec![record(0, 0.1), record(1, f64::NAN), record(2, 0.5)],
            measured_avg_power: vec![90.0],
            pbar: 100.0,
            final_accuracy: 0.5,
            total_secs: 1.0,
        };
        assert_eq!(log.accuracy_series(), vec![(0, 0.1), (2, 0.5)]);
        assert_eq!(log.best_accuracy(), 0.5);
        assert!(log.power_constraint_ok(1e-9));
    }

    #[test]
    fn power_audit_fails_when_over() {
        let log = TrainLog {
            label: "t".into(),
            records: vec![],
            measured_avg_power: vec![120.0],
            pbar: 100.0,
            final_accuracy: 0.0,
            total_secs: 0.0,
        };
        assert!(!log.power_constraint_ok(0.01));
    }

    /// Absent participation serializes as NaN, never as 0 — the regression
    /// guard for the "default 0 is indistinguishable from measured 0" gap.
    #[test]
    fn csv_distinguishes_absent_participation_from_zero() {
        let dir = std::env::temp_dir().join("ota_metrics_participation_test");
        let path = dir.join("log.csv");
        let mut with_stats = record(0, 0.3);
        with_stats.participation = Some(ParticipationStats {
            transmitting: 0,
            not_scheduled: 1,
            silenced_low_gain: 2,
            dropped_stragglers: 3,
        });
        // Exact consensus (a real measured 0) vs not-modeled (NaN).
        with_stats.consensus_distance = Some(0.0);
        let log = TrainLog {
            label: "t".into(),
            records: vec![record(0, 0.3), with_stats],
            measured_avg_power: vec![1.0],
            pbar: 2.0,
            final_accuracy: 0.3,
            total_secs: 0.1,
        };
        log.write_csv(path.to_str().unwrap()).unwrap();
        let rows = crate::util::csv::read_csv(&path).unwrap();
        let header = &rows[0];
        let i_part = header.iter().position(|h| h == "participating").unwrap();
        let i_drop = header.iter().position(|h| h == "dropped_stragglers").unwrap();
        let i_cons = header.iter().position(|h| h == "consensus_distance").unwrap();
        // Row 1: scheme without participation/consensus — NaN, not 0.
        assert_eq!(rows[1][i_part], "NaN");
        assert_eq!(rows[1][i_drop], "NaN");
        assert_eq!(rows[1][i_cons], "NaN");
        // Row 2: all-silent round — a real measured 0 (and 3 stragglers),
        // and an exact-consensus 0 distinct from the absent NaN above.
        assert_eq!(rows[2][i_part], "0");
        assert_eq!(rows[2][i_drop], "3");
        assert_eq!(rows[2][i_cons], "0");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("ota_metrics_test");
        let path = dir.join("log.csv");
        let log = TrainLog {
            label: "t".into(),
            records: vec![record(0, 0.3)],
            measured_avg_power: vec![1.0],
            pbar: 2.0,
            final_accuracy: 0.3,
            total_secs: 0.1,
        };
        log.write_csv(path.to_str().unwrap()).unwrap();
        let rows = crate::util::csv::read_csv(&path).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1][0], "0");
        std::fs::remove_dir_all(&dir).ok();
    }
}
