//! Device-side fan-out: a set of per-device transmitter states whose
//! per-round encode runs in parallel across worker threads.
//!
//! Every link scheme owns one `DeviceSet` of its concrete device type
//! (`AnalogDevice`, `DigitalDevice`, …). Encoding is embarrassingly
//! parallel — device m's frame depends only on device m's state and
//! gradient row, and every random stream is seeded per device — so the
//! fan-out through [`par_map`] is bit-identical to a sequential pass,
//! which `rust/tests/golden_schemes.rs` asserts.

use std::sync::Mutex;

use crate::util::threadpool::{default_workers, par_map};

/// A fleet of per-device transmitter states with a parallel encode path.
pub struct DeviceSet<S> {
    states: Vec<S>,
    workers: usize,
}

impl<S: Send> DeviceSet<S> {
    /// Build with one worker per available core (capped at the fleet size).
    pub fn new(states: Vec<S>) -> DeviceSet<S> {
        let workers = default_workers(states.len());
        DeviceSet { states, workers }
    }

    /// Build with an explicit worker count (`1` forces the sequential path;
    /// tests use this to prove parallel == sequential).
    pub fn with_workers(states: Vec<S>, workers: usize) -> DeviceSet<S> {
        assert!(workers >= 1);
        DeviceSet { states, workers }
    }

    pub fn len(&self) -> usize {
        self.states.len()
    }

    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Encode one frame per device, fanning the M independent encodes out
    /// across the worker threads via [`par_map`]. Results come back in
    /// device order. Each per-device mutex is locked exactly once (by
    /// whichever worker claims that index), so there is no contention and
    /// no ordering ambiguity — output is bit-identical to `workers = 1`.
    pub fn encode<T, F>(&mut self, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &mut S) -> T + Sync,
    {
        let n = self.states.len();
        if n == 0 {
            return Vec::new();
        }
        let cells: Vec<Mutex<&mut S>> = self.states.iter_mut().map(Mutex::new).collect();
        par_map(n, self.workers, |i| {
            let mut state = cells[i].lock().unwrap();
            f(i, &mut **state)
        })
    }

    /// Mean of a per-device statistic (e.g. error-accumulator norms).
    pub fn mean_over<F: Fn(&S) -> f64>(&self, f: F) -> f64 {
        if self.states.is_empty() {
            return 0.0;
        }
        self.states.iter().map(f).sum::<f64>() / self.states.len() as f64
    }

    pub fn iter(&self) -> std::slice::Iter<'_, S> {
        self.states.iter()
    }

    /// Mutable per-device access in device order (checkpoint restore walks
    /// this to reload each device's error accumulator / RNG position).
    pub fn iter_mut(&mut self) -> std::slice::IterMut<'_, S> {
        self.states.iter_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::{AnalogDevice, Projection};
    use crate::compress::DigitalPayload;
    use crate::config::Scheme;
    use crate::digital::DigitalDevice;
    use crate::util::rng::Pcg64;

    fn gradient(dim: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..dim).map(|_| rng.normal_ms(0.0, 0.5) as f32).collect()
    }

    /// Parallel analog encode must be bit-identical to the sequential path
    /// for M ∈ {1, 4, 25} devices (frames carry per-device error state, so
    /// any cross-device interference would show up here).
    #[test]
    fn analog_encode_parallel_matches_sequential() {
        let (d, s, k) = (400, 81, 20);
        let proj = Projection::generate(s - 1, d, 7);
        for m in [1usize, 4, 25] {
            let grads: Vec<Vec<f32>> = (0..m).map(|i| gradient(d, 100 + i as u64)).collect();
            let run = |workers: usize| -> Vec<Vec<f32>> {
                let states: Vec<AnalogDevice> =
                    (0..m).map(|_| AnalogDevice::new(d, k)).collect();
                let mut set = DeviceSet::with_workers(states, workers);
                // Two rounds so the error accumulators feed round 2.
                let _ = set.encode(|dev, st| st.transmit(&grads[dev], &proj, 100.0).x);
                set.encode(|dev, st| st.transmit(&grads[dev], &proj, 100.0).x)
            };
            assert_eq!(run(1), run(4), "M={m}");
        }
    }

    /// Same bit-identity for the digital pipeline (QSGD draws from a
    /// per-device RNG stream — the parallel path must not perturb it).
    #[test]
    fn digital_encode_parallel_matches_sequential() {
        let d = 256;
        for m in [1usize, 4, 25] {
            let grads: Vec<Vec<f32>> = (0..m).map(|i| gradient(d, 200 + i as u64)).collect();
            let run = |workers: usize| -> Vec<DigitalPayload> {
                let states: Vec<DigitalDevice> = (0..m)
                    .map(|i| DigitalDevice::new(Scheme::Qsgd, d, 2, i as u64))
                    .collect();
                let mut set = DeviceSet::with_workers(states, workers);
                set.encode(|dev, st| st.transmit(&grads[dev], 600.0))
            };
            let seq = run(1);
            let par = run(4);
            assert_eq!(seq.len(), par.len(), "M={m}");
            for (a, b) in seq.iter().zip(&par) {
                assert_eq!(a.reconstruction, b.reconstruction, "M={m}");
                assert_eq!(a.nnz, b.nnz, "M={m}");
                assert_eq!(a.bits, b.bits, "M={m}");
            }
        }
    }

    #[test]
    fn encode_preserves_device_order() {
        let states: Vec<u64> = (0..50).collect();
        let mut set = DeviceSet::with_workers(states, 8);
        let out = set.encode(|i, s| {
            *s += 1;
            (i as u64) * 1000 + *s
        });
        let expect: Vec<u64> = (0..50u64).map(|i| i * 1000 + i + 1).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn mean_over_statistics() {
        let set = DeviceSet::new(vec![1.0f64, 2.0, 3.0]);
        assert!((set.mean_over(|&v| v) - 2.0).abs() < 1e-12);
        let empty: DeviceSet<f64> = DeviceSet::new(Vec::new());
        assert_eq!(empty.mean_over(|&v| v), 0.0);
        assert!(empty.is_empty());
    }
}
