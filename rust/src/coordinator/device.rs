//! Unified device-side state across schemes.

use crate::analog::AnalogDevice;
use crate::config::Scheme;
use crate::digital::DigitalDevice;

/// One edge device's scheme-specific transmitter state.
pub enum DeviceState {
    Analog(AnalogDevice),
    Digital(DigitalDevice),
    /// Error-free benchmark: the device "sends" its exact gradient.
    Passthrough,
}

impl DeviceState {
    pub fn new(scheme: Scheme, dim: usize, k: usize, qsgd_levels: u32, seed: u64) -> DeviceState {
        match scheme {
            Scheme::ADsgd => DeviceState::Analog(AnalogDevice::new(dim, k)),
            Scheme::DDsgd | Scheme::SignSgd | Scheme::Qsgd => {
                DeviceState::Digital(DigitalDevice::new(scheme, dim, qsgd_levels, seed))
            }
            Scheme::ErrorFree => DeviceState::Passthrough,
        }
    }

    /// ‖Δ_m‖ for schemes that carry error accumulation, 0 otherwise.
    pub fn accumulator_norm(&self) -> f64 {
        match self {
            DeviceState::Analog(d) => d.accumulator_norm(),
            DeviceState::Digital(d) => d.accumulator_norm(),
            DeviceState::Passthrough => 0.0,
        }
    }

    pub fn as_analog_mut(&mut self) -> &mut AnalogDevice {
        match self {
            DeviceState::Analog(d) => d,
            _ => panic!("not an analog device"),
        }
    }

    pub fn as_digital_mut(&mut self) -> &mut DigitalDevice {
        match self {
            DeviceState::Digital(d) => d,
            _ => panic!("not a digital device"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructs_right_variant() {
        assert!(matches!(
            DeviceState::new(Scheme::ADsgd, 100, 5, 2, 1),
            DeviceState::Analog(_)
        ));
        assert!(matches!(
            DeviceState::new(Scheme::DDsgd, 100, 5, 2, 1),
            DeviceState::Digital(_)
        ));
        assert!(matches!(
            DeviceState::new(Scheme::ErrorFree, 100, 5, 2, 1),
            DeviceState::Passthrough
        ));
    }

    #[test]
    fn passthrough_has_no_accumulator() {
        let d = DeviceState::new(Scheme::ErrorFree, 10, 1, 2, 1);
        assert_eq!(d.accumulator_norm(), 0.0);
    }
}
