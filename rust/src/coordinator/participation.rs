//! Round-level partial participation: which devices transmit this round.
//!
//! The selector sits in front of `DeviceSet::encode` — a device that is not
//! selected never encodes a frame (its gradient is banked in its error
//! accumulator instead). Selection is **counter-based**: the uniform-K draw
//! derives a fresh RNG from `(seed, round)`, so the subset for round t does
//! not depend on call order or thread-pool size, and `K = M` selects every
//! device — bit-identical to [`ParticipationPolicy::Full`] (pinned by the
//! degeneracy golden in `rust/tests/golden_schemes.rs`).

use crate::config::ParticipationPolicy;
use crate::util::rng::counter_rng;

/// Seeded per-round device-subset selector.
#[derive(Clone, Debug)]
pub struct ParticipationSelector {
    policy: ParticipationPolicy,
    seed: u64,
}

impl ParticipationSelector {
    pub fn new(policy: ParticipationPolicy, seed: u64) -> ParticipationSelector {
        ParticipationSelector { policy, seed }
    }

    pub fn policy(&self) -> ParticipationPolicy {
        self.policy
    }

    /// The participation mask for round `t` over `gains.len()` devices
    /// (device order). Pure in `(self, t, gains)`.
    pub fn select(&self, t: usize, gains: &[f64]) -> Vec<bool> {
        let m = gains.len();
        match self.policy {
            ParticipationPolicy::Full => vec![true; m],
            ParticipationPolicy::UniformK(k) => {
                let k = k.min(m);
                let mut rng = counter_rng(self.seed, 0x5E1E_C70A, t as u64, 0);
                let mut mask = vec![false; m];
                for i in rng.sample_indices(m, k) {
                    mask[i] = true;
                }
                mask
            }
            ParticipationPolicy::GainThreshold(th) => gains.iter().map(|&h| h >= th).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_selects_everyone() {
        let s = ParticipationSelector::new(ParticipationPolicy::Full, 1);
        assert_eq!(s.select(0, &[1.0; 5]), vec![true; 5]);
    }

    #[test]
    fn uniform_k_is_seeded_and_exactly_k() {
        let a = ParticipationSelector::new(ParticipationPolicy::UniformK(3), 42);
        let b = ParticipationSelector::new(ParticipationPolicy::UniformK(3), 42);
        let gains = [1.0; 10];
        for t in 0..20 {
            let ma = a.select(t, &gains);
            assert_eq!(ma, b.select(t, &gains), "t={t}");
            assert_eq!(ma.iter().filter(|&&x| x).count(), 3, "t={t}");
            // Pure: the same round queried again gives the same subset.
            assert_eq!(ma, a.select(t, &gains));
        }
        // Subsets vary across rounds.
        assert_ne!(
            (0..20).map(|t| a.select(t, &gains)).collect::<Vec<_>>(),
            vec![a.select(0, &gains); 20]
        );
    }

    #[test]
    fn uniform_m_equals_full() {
        let full = ParticipationSelector::new(ParticipationPolicy::Full, 7);
        let k_eq_m = ParticipationSelector::new(ParticipationPolicy::UniformK(8), 7);
        let gains = [1.0; 8];
        for t in 0..10 {
            assert_eq!(full.select(t, &gains), k_eq_m.select(t, &gains));
        }
    }

    #[test]
    fn gain_threshold_compares_per_device() {
        let s = ParticipationSelector::new(ParticipationPolicy::GainThreshold(0.5), 1);
        assert_eq!(
            s.select(3, &[0.1, 0.5, 0.9, 0.49]),
            vec![false, true, true, false]
        );
    }
}
