//! The separation-based digital pipeline shared by D-DSGD, SignSGD and QSGD
//! (§III): per-round capacity budget R_t, per-device compression within it,
//! error-free transport (capacity-achieving codes assumed), PS averaging.
//!
//! # Partial participation
//!
//! The same [`ParticipationSelector`] the fading analog family uses sits in
//! front of the digital encode: an unscheduled device transmits nothing,
//! spends no energy, and banks its gradient in its error accumulator
//! ([`DigitalDevice::absorb`]) so the information arrives in a later round
//! (SignSGD/QSGD keep their source papers' no-accumulation semantics and
//! genuinely lose silent rounds). Digital devices have no CSI, so the
//! gain-threshold policy sees unit gains and degenerates to full
//! participation. The per-device bit budget stays Eq. 8's M-way split —
//! the scheduler reserves every device's slot whether or not it is used.
//! With the `Full` policy the original single-path round body runs
//! unchanged (bit-for-bit, telemetry `participation = None`); a real
//! policy reports the Option-typed counts the analog family already does.

use crate::campaign::snapshot::{self, SnapshotError, SnapshotReader, SnapshotWriter};
use crate::channel::PowerMeter;
use crate::compress::DigitalPayload;
use crate::config::{ParticipationPolicy, RunConfig};
use crate::digital::{aggregate, capacity_bits, DigitalDevice};
use crate::tensor::Matf;

use super::super::device::DeviceSet;
use super::super::participation::ParticipationSelector;
use super::diag::{DeviceOutcome, DiagSink, RoundDiagnostics};
use super::{LinkRound, LinkScheme, ParticipationStats, RoundCtx, RoundTelemetry};

pub struct DigitalLink {
    devices: DeviceSet<DigitalDevice>,
    /// Digital frames skip the MAC simulator, but each transmitting device
    /// still spends ‖x_m(t)‖² = P_t per round; the meter keeps Eq. 6
    /// auditable.
    meter: PowerMeter,
    selector: ParticipationSelector,
    channel_uses: usize,
    noise_var: f64,
    dim: usize,
    diag: Option<DiagSink>,
}

impl DigitalLink {
    pub fn new(cfg: &RunConfig, dim: usize) -> DigitalLink {
        let states: Vec<DigitalDevice> = (0..cfg.devices)
            .map(|i| {
                DigitalDevice::new(
                    cfg.scheme,
                    dim,
                    cfg.qsgd_levels,
                    cfg.seed.wrapping_add(i as u64),
                )
            })
            .collect();
        DigitalLink {
            devices: DeviceSet::new(states),
            meter: PowerMeter::new(cfg.devices),
            // Same stream constant as the fading links: the same seed +
            // policy schedules the same subsets across link families.
            selector: ParticipationSelector::new(cfg.participation, cfg.seed ^ 0x5E1),
            channel_uses: cfg.channel_uses,
            noise_var: cfg.noise_var,
            dim,
            diag: None,
        }
    }

    /// Probe epilogue shared by both round paths, read-only. `payloads[m]`
    /// is `None` for silent devices; outcome defaults to `Transmitting`
    /// when no per-device classification was run (the full-policy path).
    fn record_diag(
        &self,
        ctx: &RoundCtx,
        grads: &Matf,
        budget: f64,
        payloads: &[Option<&DigitalPayload>],
        scheduled: Option<&[bool]>,
    ) {
        let Some(sink) = &self.diag else { return };
        let m = self.devices.len();
        let mut d = RoundDiagnostics::new(ctx.t, "digital", m);
        let mut transmitting = 0usize;
        for (dev, state) in self.devices.iter().enumerate() {
            let dd = &mut d.devices[dev];
            // D-DSGD compensates with its error accumulator before
            // quantizing; the baselines quantize the raw gradient.
            dd.pre_sparsify_norm = match state.accumulator() {
                Some(acc) => super::analog::pre_sparsify_norm(grads.row(dev), acc),
                None => crate::tensor::norm(grads.row(dev)),
            };
            dd.accumulator_norm = state.accumulator_norm();
            match payloads[dev] {
                Some(p) => {
                    transmitting += 1;
                    // For digital schemes "what survived compression" is
                    // the norm of the quantized reconstruction.
                    dd.post_sparsify_norm = crate::tensor::norm(&p.reconstruction);
                    dd.payload_bits = Some(p.bits);
                    // A digital transmitter spends exactly P_t (Eq. 6).
                    dd.tx_energy = ctx.p_t;
                    dd.outcome = DeviceOutcome::Transmitting;
                }
                None => {
                    dd.payload_bits = None;
                    dd.outcome = match scheduled {
                        Some(s) if !s[dev] => DeviceOutcome::NotScheduled,
                        _ => DeviceOutcome::Transmitting,
                    };
                }
            }
        }
        d.power_budget = ctx.p_t;
        // Digital devices spend the full budget whenever they transmit, so
        // headroom is 0 with any transmitter and P_t on silent rounds.
        d.power_headroom = if transmitting > 0 { 0.0 } else { ctx.p_t };
        d.quant_budget_bits = Some(budget);
        d.effective_snr_db =
            super::diag::snr_db(transmitting as f64 * ctx.p_t, self.channel_uses, self.noise_var);
        sink.record(d);
    }
}

impl LinkScheme for DigitalLink {
    fn round(&mut self, ctx: &RoundCtx, grads: &Matf) -> LinkRound {
        let m = self.devices.len();
        debug_assert_eq!(grads.rows, m);
        // Eq. 8: this round's per-device bit budget.
        let budget = capacity_bits(self.channel_uses, m, ctx.p_t, self.noise_var);

        if self.selector.policy() == ParticipationPolicy::Full {
            // The original always-on path, untouched (and untouchable: the
            // seed golden pins it).
            let payloads: Vec<DigitalPayload> = {
                let _sp = crate::util::prof::span("encode");
                self.devices
                    .encode(|dev, state| state.transmit(grads.row(dev), budget))
            };
            // Record what the compressors actually spent — the budget is a
            // bound, not an attainment; undershoot must be visible in logs.
            let bits = payloads.iter().map(|p| p.bits).fold(0.0, f64::max);
            assert!(
                bits <= budget * (1.0 + 1e-9) + 1e-9,
                "compressor overshot the capacity budget: {bits} > {budget} bits"
            );
            self.meter.add_uniform_round(ctx.p_t);
            let refs: Vec<Option<&DigitalPayload>> = payloads.iter().map(Some).collect();
            self.record_diag(ctx, grads, budget, &refs, None);
            return LinkRound {
                ghat: aggregate(&payloads, self.dim),
                telemetry: RoundTelemetry {
                    bits_per_device: bits,
                    amp_iterations: 0,
                    participation: None,
                    consensus_distance: None,
                },
            };
        }

        // Partial participation: no CSI in the digital pipe, so selection
        // sees unit gains (gain-threshold degenerates to full).
        let scheduled = self.selector.select(ctx.t, &vec![1.0; m]);
        let frames: Vec<Option<DigitalPayload>> = {
            let _sp = crate::util::prof::span("encode");
            self.devices.encode(|dev, state| {
                if scheduled[dev] {
                    Some(state.transmit(grads.row(dev), budget))
                } else {
                    state.absorb(grads.row(dev));
                    None
                }
            })
        };
        let mut stats = ParticipationStats::default();
        for (dev, frame) in frames.iter().enumerate() {
            if frame.is_some() {
                stats.transmitting += 1;
                self.meter.add(dev, ctx.p_t);
            } else {
                stats.not_scheduled += 1;
            }
        }
        self.meter.end_round();
        let refs: Vec<Option<&DigitalPayload>> = frames.iter().map(|f| f.as_ref()).collect();
        self.record_diag(ctx, grads, budget, &refs, Some(&scheduled));
        let payloads: Vec<DigitalPayload> = frames.into_iter().flatten().collect();
        let bits = payloads.iter().map(|p| p.bits).fold(0.0, f64::max);
        assert!(
            bits <= budget * (1.0 + 1e-9) + 1e-9,
            "compressor overshot the capacity budget: {bits} > {budget} bits"
        );
        LinkRound {
            ghat: aggregate(&payloads, self.dim),
            telemetry: RoundTelemetry {
                bits_per_device: bits,
                amp_iterations: 0,
                participation: Some(stats),
                consensus_distance: None,
            },
        }
    }

    fn accumulator_norm(&self) -> f64 {
        self.devices.mean_over(|d| d.accumulator_norm())
    }

    fn measured_avg_power(&self) -> Vec<f64> {
        self.meter.report(self.channel_uses).averages()
    }

    fn name(&self) -> &'static str {
        "digital"
    }

    fn probe(&mut self, sink: Option<DiagSink>) {
        self.diag = sink;
    }

    /// Per device: the D-DSGD error accumulator (absent for the
    /// no-accumulation baselines) and the QSGD stochastic-rounding RNG
    /// position (absent for deterministic compressors); plus the Eq. 6
    /// meter. The participation selector is counter-based and needs no
    /// storage.
    fn snapshot(&self, w: &mut SnapshotWriter) {
        w.u64(self.devices.len() as u64);
        for dev in self.devices.iter() {
            match dev.accumulator() {
                Some(acc) => {
                    w.u8(1);
                    w.vec_f32(acc);
                }
                None => w.u8(0),
            }
            match dev.rng_state() {
                Some(st) => {
                    w.u8(1);
                    snapshot::write_rng(w, st);
                }
                None => w.u8(0),
            }
        }
        snapshot::write_meter(w, &self.meter);
    }

    fn restore(&mut self, r: &mut SnapshotReader<'_>) -> Result<(), SnapshotError> {
        let n = r.u64()? as usize;
        if n != self.devices.len() {
            return Err(SnapshotError::Corrupt(format!(
                "snapshot has {n} devices, link has {}",
                self.devices.len()
            )));
        }
        let dim = self.dim;
        for dev in self.devices.iter_mut() {
            let has_accum = r.u8()? != 0;
            if has_accum != dev.accumulator().is_some() {
                return Err(SnapshotError::Corrupt(
                    "accumulator presence differs from the scheme's".into(),
                ));
            }
            if has_accum {
                let acc = r.vec_f32()?;
                if acc.len() != dim {
                    return Err(SnapshotError::Corrupt(format!(
                        "accumulator length {} != model dimension {dim}",
                        acc.len()
                    )));
                }
                dev.load_accumulator(&acc);
            }
            let has_rng = r.u8()? != 0;
            if has_rng != dev.rng_state().is_some() {
                return Err(SnapshotError::Corrupt(
                    "compressor RNG presence differs from the scheme's".into(),
                ));
            }
            if has_rng {
                dev.restore_rng(snapshot::read_rng(r)?);
            }
        }
        snapshot::read_meter(r, &mut self.meter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Scheme};
    use crate::util::rng::Pcg64;

    fn grads(m: usize, d: usize) -> Matf {
        let mut rng = Pcg64::new(3);
        Matf::from_vec(m, d, (0..m * d).map(|_| rng.normal() as f32).collect())
    }

    fn link_cfg(scheme: Scheme) -> RunConfig {
        RunConfig {
            scheme,
            devices: 4,
            channel_uses: 128,
            ..presets::smoke()
        }
    }

    #[test]
    fn bits_are_actual_and_within_budget() {
        let d = 256;
        let cfg = link_cfg(Scheme::DDsgd);
        let mut link = DigitalLink::new(&cfg, d);
        let out = link.round(&RoundCtx { t: 0, p_t: 500.0, deadline: None }, &grads(4, d));
        let budget = capacity_bits(128, 4, 500.0, cfg.noise_var);
        assert!(out.telemetry.bits_per_device > 0.0);
        assert!(out.telemetry.bits_per_device <= budget);
        assert_eq!(out.ghat.len(), d);
    }

    #[test]
    fn zero_budget_is_silent_not_fatal() {
        // P̄ = 1 regime (Fig. 6): R_t admits nothing; devices stay silent
        // but still spend P_t of energy.
        let d = 256;
        let cfg = link_cfg(Scheme::DDsgd);
        let mut link = DigitalLink::new(&cfg, d);
        let out = link.round(&RoundCtx { t: 0, p_t: 1.0, deadline: None }, &grads(4, d));
        assert_eq!(out.telemetry.bits_per_device, 0.0);
        assert!(out.ghat.iter().all(|&v| v == 0.0));
        assert_eq!(link.measured_avg_power(), vec![1.0; 4]);
    }

    #[test]
    fn power_audit_averages_pt() {
        let d = 64;
        let cfg = link_cfg(Scheme::SignSgd);
        let mut link = DigitalLink::new(&cfg, d);
        let g = grads(4, d);
        link.round(&RoundCtx { t: 0, p_t: 300.0, deadline: None }, &g);
        link.round(&RoundCtx { t: 1, p_t: 100.0, deadline: None }, &g);
        assert_eq!(link.measured_avg_power(), vec![200.0; 4]);
    }

    #[test]
    fn uniform_k_schedules_exactly_k_and_banks_silent_gradients() {
        let d = 256;
        let cfg = RunConfig {
            participation: crate::config::ParticipationPolicy::UniformK(2),
            ..link_cfg(Scheme::DDsgd)
        };
        let mut link = DigitalLink::new(&cfg, d);
        let g = grads(4, d);
        for t in 0..3 {
            let out = link.round(&RoundCtx { t, p_t: 500.0, deadline: None }, &g);
            let stats = out.telemetry.participation.expect("scheduled link reports stats");
            assert_eq!(stats.transmitting, 2, "t={t}");
            assert_eq!(stats.not_scheduled, 2, "t={t}");
            assert_eq!(stats.total(), 4, "t={t}");
        }
        // Silent D-DSGD devices banked their gradients (error accumulation
        // engaged beyond the compression residue alone: a fully-banked
        // gradient has full norm).
        assert!(link.accumulator_norm() > 0.0);
        // Only transmitting devices spent energy: with K = 2 of 4 each
        // round, the average per-device power is around P_t/2, never P_t
        // for everyone.
        let powers = link.measured_avg_power();
        assert!(powers.iter().sum::<f64>() < 4.0 * 500.0 - 1e-9);
        for &p in &powers {
            assert!(p <= 500.0 * (1.0 + 1e-9), "avg power {p}");
        }
    }

    #[test]
    fn gain_threshold_without_csi_degenerates_to_full() {
        // Digital devices have no channel gains; the selector sees h ≡ 1,
        // so any threshold ≤ 1 schedules everyone (and reports the counts,
        // because a policy *is* configured).
        let d = 128;
        let cfg = RunConfig {
            participation: crate::config::ParticipationPolicy::GainThreshold(0.5),
            ..link_cfg(Scheme::SignSgd)
        };
        let mut link = DigitalLink::new(&cfg, d);
        let out = link.round(&RoundCtx { t: 0, p_t: 500.0, deadline: None }, &grads(4, d));
        let stats = out.telemetry.participation.unwrap();
        assert_eq!(stats.transmitting, 4);
        assert_eq!(stats.not_scheduled, 0);
    }

    #[test]
    fn signsgd_silent_rounds_do_not_accumulate() {
        // The baselines keep their papers' no-accumulation semantics: a
        // silent round genuinely loses the gradient.
        let d = 128;
        let cfg = RunConfig {
            participation: crate::config::ParticipationPolicy::UniformK(1),
            ..link_cfg(Scheme::SignSgd)
        };
        let mut link = DigitalLink::new(&cfg, d);
        link.round(&RoundCtx { t: 0, p_t: 500.0, deadline: None }, &grads(4, d));
        assert_eq!(link.accumulator_norm(), 0.0);
    }

    #[test]
    fn probe_reports_bits_budget_and_outcomes() {
        let d = 256;
        let cfg = RunConfig {
            participation: crate::config::ParticipationPolicy::UniformK(2),
            ..link_cfg(Scheme::DDsgd)
        };
        let mut link = DigitalLink::new(&cfg, d);
        let sink = DiagSink::new();
        link.probe(Some(sink.clone()));
        link.round(&RoundCtx { t: 0, p_t: 500.0, deadline: None }, &grads(4, d));
        let diags = sink.drain();
        assert_eq!(diags.len(), 1);
        let diag = &diags[0];
        let budget = capacity_bits(128, 4, 500.0, cfg.noise_var);
        assert_eq!(diag.quant_budget_bits, Some(budget));
        assert!(diag.effective_snr_db.is_some());
        let (tx, ns, _, _) = diag.participation_counts();
        assert_eq!((tx, ns), (2, 2));
        for dd in &diag.devices {
            match dd.outcome {
                DeviceOutcome::Transmitting => {
                    let bits = dd.payload_bits.expect("transmitters report payload bits");
                    assert!(bits > 0.0 && bits <= budget, "{bits} vs {budget}");
                    // Digital transmitters spend the whole budget: no headroom.
                    assert_eq!(dd.tx_energy, 500.0);
                    assert!(dd.post_sparsify_norm > 0.0);
                }
                _ => {
                    assert_eq!(dd.payload_bits, None);
                    assert_eq!(dd.tx_energy, 0.0);
                }
            }
            assert!(dd.pre_sparsify_norm > 0.0);
        }
        assert_eq!(diag.power_headroom, 0.0);
    }

    #[test]
    fn ddsgd_accumulates_errors() {
        let d = 256;
        let cfg = link_cfg(Scheme::DDsgd);
        let mut link = DigitalLink::new(&cfg, d);
        // Tight budget leaves residue in the D-DSGD accumulators.
        link.round(&RoundCtx { t: 0, p_t: 500.0, deadline: None }, &grads(4, d));
        assert!(link.accumulator_norm() > 0.0);
    }
}
