//! The separation-based digital pipeline shared by D-DSGD, SignSGD and QSGD
//! (§III): per-round capacity budget R_t, per-device compression within it,
//! error-free transport (capacity-achieving codes assumed), PS averaging.

use crate::channel::PowerMeter;
use crate::compress::DigitalPayload;
use crate::config::RunConfig;
use crate::digital::{aggregate, capacity_bits, DigitalDevice};
use crate::tensor::Matf;

use super::super::device::DeviceSet;
use super::{LinkRound, LinkScheme, RoundCtx, RoundTelemetry};

pub struct DigitalLink {
    devices: DeviceSet<DigitalDevice>,
    /// Digital frames skip the MAC simulator, but each device still spends
    /// ‖x_m(t)‖² = P_t per round; the meter keeps Eq. 6 auditable.
    meter: PowerMeter,
    channel_uses: usize,
    noise_var: f64,
    dim: usize,
}

impl DigitalLink {
    pub fn new(cfg: &RunConfig, dim: usize) -> DigitalLink {
        let states: Vec<DigitalDevice> = (0..cfg.devices)
            .map(|i| {
                DigitalDevice::new(
                    cfg.scheme,
                    dim,
                    cfg.qsgd_levels,
                    cfg.seed.wrapping_add(i as u64),
                )
            })
            .collect();
        DigitalLink {
            devices: DeviceSet::new(states),
            meter: PowerMeter::new(cfg.devices),
            channel_uses: cfg.channel_uses,
            noise_var: cfg.noise_var,
            dim,
        }
    }
}

impl LinkScheme for DigitalLink {
    fn round(&mut self, ctx: &RoundCtx, grads: &Matf) -> LinkRound {
        let m = self.devices.len();
        debug_assert_eq!(grads.rows, m);
        // Eq. 8: this round's per-device bit budget.
        let budget = capacity_bits(self.channel_uses, m, ctx.p_t, self.noise_var);
        let payloads: Vec<DigitalPayload> = self
            .devices
            .encode(|dev, state| state.transmit(grads.row(dev), budget));
        // Record what the compressors actually spent — the budget is a
        // bound, not an attainment; undershoot must be visible in the logs.
        let bits = payloads.iter().map(|p| p.bits).fold(0.0, f64::max);
        assert!(
            bits <= budget * (1.0 + 1e-9) + 1e-9,
            "compressor overshot the capacity budget: {bits} > {budget} bits"
        );
        self.meter.add_uniform_round(ctx.p_t);
        LinkRound {
            ghat: aggregate(&payloads, self.dim),
            telemetry: RoundTelemetry {
                bits_per_device: bits,
                amp_iterations: 0,
                participation: None,
            },
        }
    }

    fn accumulator_norm(&self) -> f64 {
        self.devices.mean_over(|d| d.accumulator_norm())
    }

    fn measured_avg_power(&self) -> Vec<f64> {
        self.meter.report(self.channel_uses).averages()
    }

    fn name(&self) -> &'static str {
        "digital"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Scheme};
    use crate::util::rng::Pcg64;

    fn grads(m: usize, d: usize) -> Matf {
        let mut rng = Pcg64::new(3);
        Matf::from_vec(m, d, (0..m * d).map(|_| rng.normal() as f32).collect())
    }

    fn link_cfg(scheme: Scheme) -> RunConfig {
        RunConfig {
            scheme,
            devices: 4,
            channel_uses: 128,
            ..presets::smoke()
        }
    }

    #[test]
    fn bits_are_actual_and_within_budget() {
        let d = 256;
        let cfg = link_cfg(Scheme::DDsgd);
        let mut link = DigitalLink::new(&cfg, d);
        let out = link.round(&RoundCtx { t: 0, p_t: 500.0, deadline: None }, &grads(4, d));
        let budget = capacity_bits(128, 4, 500.0, cfg.noise_var);
        assert!(out.telemetry.bits_per_device > 0.0);
        assert!(out.telemetry.bits_per_device <= budget);
        assert_eq!(out.ghat.len(), d);
    }

    #[test]
    fn zero_budget_is_silent_not_fatal() {
        // P̄ = 1 regime (Fig. 6): R_t admits nothing; devices stay silent
        // but still spend P_t of energy.
        let d = 256;
        let cfg = link_cfg(Scheme::DDsgd);
        let mut link = DigitalLink::new(&cfg, d);
        let out = link.round(&RoundCtx { t: 0, p_t: 1.0, deadline: None }, &grads(4, d));
        assert_eq!(out.telemetry.bits_per_device, 0.0);
        assert!(out.ghat.iter().all(|&v| v == 0.0));
        assert_eq!(link.measured_avg_power(), vec![1.0; 4]);
    }

    #[test]
    fn power_audit_averages_pt() {
        let d = 64;
        let cfg = link_cfg(Scheme::SignSgd);
        let mut link = DigitalLink::new(&cfg, d);
        let g = grads(4, d);
        link.round(&RoundCtx { t: 0, p_t: 300.0, deadline: None }, &g);
        link.round(&RoundCtx { t: 1, p_t: 100.0, deadline: None }, &g);
        assert_eq!(link.measured_avg_power(), vec![200.0; 4]);
    }

    #[test]
    fn ddsgd_accumulates_errors() {
        let d = 256;
        let cfg = link_cfg(Scheme::DDsgd);
        let mut link = DigitalLink::new(&cfg, d);
        // Tight budget leaves residue in the D-DSGD accumulators.
        link.round(&RoundCtx { t: 0, p_t: 500.0, deadline: None }, &grads(4, d));
        assert!(link.accumulator_norm() > 0.0);
    }
}
