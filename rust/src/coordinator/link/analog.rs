//! A-DSGD over the Gaussian MAC (Algorithm 1): sparsify → project →
//! power-scale → superpose → AMP. Owns both decoder variants and the §IV-A
//! mean-removal phase transition that used to leak into the trainer.

use crate::amp::AmpConfig;
use crate::analog::{AnalogDevice, AnalogPs, Projection};
use crate::campaign::snapshot::{self, SnapshotError, SnapshotReader, SnapshotWriter};
use crate::channel::GaussianMac;
use crate::config::RunConfig;
use crate::tensor::Matf;

use super::super::device::DeviceSet;
use super::diag::{DeviceDiag, DiagSink, RoundDiagnostics};
use super::{LinkRound, LinkScheme, RoundCtx, RoundTelemetry};

pub struct AnalogLink {
    devices: DeviceSet<AnalogDevice>,
    mac: GaussianMac,
    /// Standard-framing decoder (s̃ = s − 1), used after the warm-up phase.
    ps_std: AnalogPs,
    /// Mean-removal decoder (s̃ = s − 2) for the first
    /// `mean_removal_rounds` iterations; dropped once past its phase to
    /// release the projection matrix.
    ps_mr: Option<AnalogPs>,
    mean_removal_rounds: usize,
    channel_uses: usize,
    diag: Option<DiagSink>,
}

/// ‖g + Δ‖ for one device, read-only (f64 accumulation over the existing
/// buffers — the same value `sparsify_step` sees, computed without running
/// it). Shared by the static and fading analog probes.
pub(super) fn pre_sparsify_norm(g: &[f32], accum: &[f32]) -> f64 {
    debug_assert_eq!(g.len(), accum.len());
    g.iter()
        .zip(accum)
        .map(|(&gi, &ai)| {
            let v = (gi + ai) as f64;
            v * v
        })
        .sum::<f64>()
        .sqrt()
}

/// ‖sp_k(g_ec)‖ via the disjoint-support identity
/// ‖g_sp‖² = ‖g_ec‖² − ‖Δ(t+1)‖² (sparsification keeps the top-k entries
/// and banks the rest, so the kept and banked parts are orthogonal).
pub(super) fn post_sparsify_norm(pre_norm: f64, accum_norm_after: f64) -> f64 {
    (pre_norm * pre_norm - accum_norm_after * accum_norm_after).max(0.0).sqrt()
}

/// Shared constructor guts for the static *and* fading analog links:
/// per-device states, the MAC, and both decoders, all seeded from the same
/// RNG-stream constants (`seed ^ 0xA57D` / `^ 0xA57E` projections,
/// `^ 0xC4A` MAC noise). The h ≡ 1 degeneracy golden requires
/// `FadingAnalogLink` to stay in lockstep with `AnalogLink` forever —
/// building both from this single recipe makes drift impossible.
pub(super) fn analog_parts(
    cfg: &RunConfig,
    dim: usize,
) -> (Vec<AnalogDevice>, GaussianMac, AnalogPs, Option<AnalogPs>) {
    let amp_cfg = AmpConfig {
        max_iters: cfg.amp_iters,
        tol: cfg.amp_tol,
        threshold_mult: cfg.amp_threshold_mult as f32,
    };
    let states: Vec<AnalogDevice> = (0..cfg.devices)
        .map(|_| AnalogDevice::new(dim, cfg.sparsity))
        .collect();
    let ps_std = AnalogPs::new(
        Projection::generate(cfg.channel_uses - 1, dim, cfg.seed ^ 0xA57D),
        amp_cfg,
    );
    let ps_mr = (cfg.mean_removal_rounds > 0).then(|| {
        AnalogPs::new(
            Projection::generate(cfg.channel_uses - 2, dim, cfg.seed ^ 0xA57E),
            amp_cfg,
        )
    });
    let mac = GaussianMac::new(cfg.channel_uses, cfg.devices, cfg.noise_var, cfg.seed ^ 0xC4A);
    (states, mac, ps_std, ps_mr)
}

/// Checkpoint the round state the static *and* fading analog links share:
/// per-device error accumulators plus the MAC's noise-stream position and
/// power meter. Everything else (projections, decoders, the counter-based
/// scenario generators) is rebuilt from the config.
pub(super) fn snapshot_analog_state(
    w: &mut SnapshotWriter,
    devices: &DeviceSet<AnalogDevice>,
    mac: &GaussianMac,
) {
    w.u64(devices.len() as u64);
    for dev in devices.iter() {
        w.vec_f32(dev.accumulator());
    }
    snapshot::write_rng(w, mac.rng_state());
    snapshot::write_meter(w, mac.meter());
}

pub(super) fn restore_analog_state(
    r: &mut SnapshotReader<'_>,
    devices: &mut DeviceSet<AnalogDevice>,
    mac: &mut GaussianMac,
) -> Result<(), SnapshotError> {
    let n = r.u64()? as usize;
    if n != devices.len() {
        return Err(SnapshotError::Corrupt(format!(
            "snapshot has {n} devices, link has {}",
            devices.len()
        )));
    }
    for dev in devices.iter_mut() {
        let acc = r.vec_f32()?;
        if acc.len() != dev.accumulator().len() {
            return Err(SnapshotError::Corrupt(format!(
                "accumulator length {} != model dimension {}",
                acc.len(),
                dev.accumulator().len()
            )));
        }
        dev.load_accumulator(&acc);
    }
    mac.restore_rng(snapshot::read_rng(r)?);
    snapshot::read_meter(r, mac.meter_mut())
}

impl AnalogLink {
    pub fn new(cfg: &RunConfig, dim: usize) -> AnalogLink {
        let (states, mac, ps_std, ps_mr) = analog_parts(cfg, dim);
        AnalogLink {
            devices: DeviceSet::new(states),
            mac,
            ps_std,
            ps_mr,
            mean_removal_rounds: cfg.mean_removal_rounds,
            channel_uses: cfg.channel_uses,
            diag: None,
        }
    }
}

impl LinkScheme for AnalogLink {
    fn round(&mut self, ctx: &RoundCtx, grads: &Matf) -> LinkRound {
        let mean_removal = ctx.t < self.mean_removal_rounds;
        let s = self.channel_uses;
        let p_t = ctx.p_t;
        // Probe prologue: ‖g + Δ(t)‖ per device, read before encode mutates
        // the accumulators. Only runs while a sink is installed.
        let pre_norms: Option<Vec<f64>> = self.diag.as_ref().map(|_| {
            self.devices
                .iter()
                .enumerate()
                .map(|(dev, state)| pre_sparsify_norm(grads.row(dev), state.accumulator()))
                .collect()
        });
        let frames: Vec<Vec<f32>> = {
            let _sp = crate::util::prof::span("encode");
            if mean_removal {
                let proj = self
                    .ps_mr
                    .as_ref()
                    .expect("mean-removal decoder")
                    .projection();
                self.devices.encode(|dev, state| {
                    state
                        .transmit_mean_removed(grads.row(dev), proj, p_t, s)
                        .x
                })
            } else {
                let proj = self.ps_std.projection();
                self.devices
                    .encode(|dev, state| state.transmit(grads.row(dev), proj, p_t).x)
            }
        };
        let y = {
            let _sp = crate::util::prof::span("transmit");
            self.mac.transmit(&frames)
        };
        let (ghat, trace) = {
            let _sp = crate::util::prof::span("decode_amp");
            if mean_removal {
                self.ps_mr
                    .as_ref()
                    .expect("mean-removal decoder")
                    .decode_mean_removed(&y)
            } else {
                self.ps_std.decode(&y)
            }
        };
        if let (Some(sink), Some(pre)) = (&self.diag, &pre_norms) {
            let mut d = RoundDiagnostics::new(ctx.t, "A-DSGD", self.devices.len());
            let mut received = 0.0;
            let mut max_energy: f64 = 0.0;
            for (dev, state) in self.devices.iter().enumerate() {
                let energy = crate::tensor::norm_sq(&frames[dev]);
                let acc = state.accumulator_norm();
                let dd: &mut DeviceDiag = &mut d.devices[dev];
                dd.pre_sparsify_norm = pre[dev];
                dd.post_sparsify_norm = post_sparsify_norm(pre[dev], acc);
                dd.accumulator_norm = acc;
                dd.tx_energy = energy;
                received += energy;
                max_energy = max_energy.max(energy);
            }
            d.power_budget = p_t;
            d.power_headroom = p_t - max_energy;
            d.effective_snr_db = super::diag::snr_db(received, s, self.mac.noise_var);
            d.amp_iterations = trace.iterations;
            d.amp_final_residual = trace.tau.last().copied();
            sink.record(d);
        }
        // Free the mean-removal projection once past its phase.
        if !mean_removal && self.ps_mr.is_some() {
            self.ps_mr = None;
        }
        LinkRound {
            ghat,
            telemetry: RoundTelemetry {
                bits_per_device: 0.0,
                amp_iterations: trace.iterations,
                // All M devices transmit every round on the static MAC;
                // participation is not modeled (None ≠ "0 participated"),
                // and one PS model means no consensus distance to measure.
                participation: None,
                consensus_distance: None,
            },
        }
    }

    fn accumulator_norm(&self) -> f64 {
        self.devices.mean_over(|d| d.accumulator_norm())
    }

    fn measured_avg_power(&self) -> Vec<f64> {
        self.mac.power_report().averages()
    }

    fn name(&self) -> &'static str {
        "A-DSGD"
    }

    fn probe(&mut self, sink: Option<DiagSink>) {
        self.diag = sink;
    }

    fn snapshot(&self, w: &mut SnapshotWriter) {
        snapshot_analog_state(w, &self.devices, &self.mac);
    }

    fn restore(&mut self, r: &mut SnapshotReader<'_>) -> Result<(), SnapshotError> {
        restore_analog_state(r, &mut self.devices, &mut self.mac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::rng::Pcg64;

    fn small_cfg() -> RunConfig {
        RunConfig {
            devices: 6,
            channel_uses: 101,
            sparsity: 25,
            mean_removal_rounds: 2,
            amp_iters: 30,
            ..presets::smoke()
        }
    }

    fn grads(m: usize, d: usize, seed: u64) -> Matf {
        let mut rng = Pcg64::new(seed);
        Matf::from_vec(
            m,
            d,
            (0..m * d).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect(),
        )
    }

    #[test]
    fn mean_removal_phase_then_standard() {
        let d = 500;
        let cfg = small_cfg();
        let mut link = AnalogLink::new(&cfg, d);
        let g = grads(6, d, 11);
        let mut amp_iters = Vec::new();
        for t in 0..4 {
            let out = link.round(&RoundCtx { t, p_t: 500.0, deadline: None }, &g);
            assert_eq!(out.ghat.len(), d);
            assert_eq!(out.telemetry.bits_per_device, 0.0);
            amp_iters.push(out.telemetry.amp_iterations);
        }
        // Both decoder variants actually ran AMP (t<2 mean-removal, t≥2 std).
        assert!(amp_iters[..2].iter().any(|&it| it > 0), "{amp_iters:?}");
        assert!(amp_iters[2..].iter().any(|&it| it > 0), "{amp_iters:?}");
        // Past the phase the mean-removal decoder is released.
        assert!(link.ps_mr.is_none());
    }

    #[test]
    fn power_metered_through_mac() {
        let d = 500;
        let cfg = small_cfg();
        let mut link = AnalogLink::new(&cfg, d);
        let g = grads(6, d, 12);
        for t in 0..3 {
            link.round(&RoundCtx { t, p_t: cfg.pbar, deadline: None }, &g);
        }
        // Eq. 12 framing spends exactly P_t per round per device.
        for &p in &link.measured_avg_power() {
            assert!((p - cfg.pbar).abs() < 1e-2 * cfg.pbar, "avg power {p}");
        }
    }

    #[test]
    fn probe_is_read_only_and_reports_the_round() {
        let d = 500;
        let cfg = small_cfg();
        let g = grads(6, d, 21);
        let run = |probe: bool| {
            let mut link = AnalogLink::new(&cfg, d);
            let sink = DiagSink::new();
            if probe {
                link.probe(Some(sink.clone()));
            }
            let mut ghats = Vec::new();
            for t in 0..3 {
                ghats.push(link.round(&RoundCtx { t, p_t: 500.0, deadline: None }, &g).ghat);
            }
            (ghats, sink.drain())
        };
        let (ghat_off, diags_off) = run(false);
        let (ghat_on, diags_on) = run(true);
        // Bit-identical trajectories with probes on or off.
        assert_eq!(ghat_off, ghat_on);
        assert!(diags_off.is_empty());
        assert_eq!(diags_on.len(), 3);
        for diag in &diags_on {
            assert_eq!(diag.scheme, "A-DSGD");
            assert_eq!(diag.devices.len(), 6);
            assert!(diag.amp_iterations > 0);
            assert!(diag.amp_final_residual.is_some());
            assert!(diag.effective_snr_db.is_some());
            for dd in &diag.devices {
                // Eq. 12 framing spends exactly P_t → headroom ≈ 0.
                assert!((dd.tx_energy - 500.0).abs() < 1.0, "{}", dd.tx_energy);
                assert!(dd.pre_sparsify_norm >= dd.post_sparsify_norm);
                assert!(dd.post_sparsify_norm > 0.0);
            }
            assert!(diag.power_headroom.abs() < 1.0);
        }
    }

    #[test]
    fn error_accumulators_engage() {
        let d = 500;
        let cfg = small_cfg();
        let mut link = AnalogLink::new(&cfg, d);
        assert_eq!(link.accumulator_norm(), 0.0);
        link.round(&RoundCtx { t: 0, p_t: 500.0, deadline: None }, &grads(6, d, 13));
        assert!(link.accumulator_norm() > 0.0);
    }
}
