//! The noiseless shared-link benchmark: the PS receives the exact average
//! gradient. No channel, no compression, no transmit energy.

use crate::campaign::snapshot::{SnapshotError, SnapshotReader, SnapshotWriter};
use crate::tensor::Matf;

use super::diag::{DiagSink, RoundDiagnostics};
use super::{LinkRound, LinkScheme, RoundCtx, RoundTelemetry};

pub struct ErrorFreeLink {
    devices: usize,
    dim: usize,
    diag: Option<DiagSink>,
}

impl ErrorFreeLink {
    pub fn new(devices: usize, dim: usize) -> ErrorFreeLink {
        assert!(devices > 0);
        ErrorFreeLink { devices, dim, diag: None }
    }
}

impl LinkScheme for ErrorFreeLink {
    fn round(&mut self, ctx: &RoundCtx, grads: &Matf) -> LinkRound {
        debug_assert_eq!(grads.rows, self.devices);
        debug_assert_eq!(grads.cols, self.dim);
        let mut avg = vec![0f32; self.dim];
        for dev in 0..self.devices {
            crate::tensor::axpy(1.0 / self.devices as f32, grads.row(dev), &mut avg);
        }
        if let Some(sink) = &self.diag {
            // Nothing is sparsified and nothing radiates: pre == post, zero
            // energy, full budget headroom, no noise → no SNR.
            let mut d = RoundDiagnostics::new(ctx.t, "error-free", self.devices);
            for dev in 0..self.devices {
                let n = crate::tensor::norm(grads.row(dev));
                d.devices[dev].pre_sparsify_norm = n;
                d.devices[dev].post_sparsify_norm = n;
            }
            d.power_budget = ctx.p_t;
            d.power_headroom = ctx.p_t;
            sink.record(d);
        }
        LinkRound {
            ghat: avg,
            telemetry: RoundTelemetry::default(),
        }
    }

    fn probe(&mut self, sink: Option<DiagSink>) {
        self.diag = sink;
    }

    fn accumulator_norm(&self) -> f64 {
        0.0
    }

    fn measured_avg_power(&self) -> Vec<f64> {
        vec![0.0; self.devices]
    }

    fn name(&self) -> &'static str {
        "error-free"
    }

    /// The noiseless link is stateless round to round — nothing to save.
    fn snapshot(&self, _w: &mut SnapshotWriter) {}

    fn restore(&mut self, _r: &mut SnapshotReader<'_>) -> Result<(), SnapshotError> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averages_exactly() {
        let grads = Matf::from_vec(2, 3, vec![1.0, 2.0, 3.0, 3.0, 4.0, 5.0]);
        let mut link = ErrorFreeLink::new(2, 3);
        let out = link.round(&RoundCtx { t: 0, p_t: 100.0, deadline: None }, &grads);
        assert_eq!(out.ghat, vec![2.0, 3.0, 4.0]);
        assert_eq!(out.telemetry.bits_per_device, 0.0);
        assert_eq!(out.telemetry.amp_iterations, 0);
        assert_eq!(link.measured_avg_power(), vec![0.0, 0.0]);
    }
}
