//! Decentralized over-the-air DSGD: no parameter server. Every device
//! keeps its own model replica θ_i and one round is
//!
//! 1. **Encode** — device i error-compensates, sparsifies and projects its
//!    gradient g_i(θ_i) exactly as Algorithm 1 (the [`AnalogDevice`]
//!    pipeline, same projection seeds as the star link), transmitting
//!    blind at full power P_t: with one broadcast serving many receivers
//!    there is no single channel to invert, so D2D is inherently the
//!    no-CSI variant.
//! 2. **Neighborhood reception** — receiver i superposes its closed
//!    neighborhood over per-edge gains: y_i = Σ_{j∈N(i)} h_ij·x_j + x_i +
//!    z(t) (a device knows its own frame and folds it in digitally — the
//!    standard half-duplex assumption). The per-edge gains come from a
//!    counter-based [`FadingProcess`] keyed by the *unordered* pair id, so
//!    h_ij = h_ji (channel reciprocity), and the ambient noise z(t) is one
//!    shared per-round draw from the same RNG stream the star MAC uses —
//!    modeling a common broadcast round. That choice is what makes the
//!    fully-connected degeneracy *exact*: with h ≡ 1 every receiver hears
//!    bit-for-bit the star MAC's y(t), so fully-connected D2D collapses to
//!    star A-DSGD (pinned in `rust/tests/golden_schemes.rs`). The blind
//!    decode reuses the static [`AnalogPs`]: the last channel use carries
//!    Σ_j h_ij·√α_j, exactly the normalizer the decoder divides by, so
//!    ĝ_i estimates the gain-weighted neighborhood-average gradient.
//! 3. **Consensus + local step** — Metropolis mixing in deviation form,
//!    θ̃_i = θ_i + Σ_j W_ij (θ_j − θ_i) (exact model exchange at the
//!    consensus layer; the bandwidth-limited d-dimensional traffic is the
//!    over-the-air gradient payload above), then the local optimizer step
//!    θ_i ← θ̃_i − Adam_i(ĝ_i). The deviation form makes "all replicas
//!    equal ⇒ mixing is a bit-exact no-op", which the degeneracy golden
//!    depends on.
//!
//! Energy accounting: each broadcast is radiated once regardless of how
//! many neighbors hear it, so the [`PowerMeter`] records ‖x_i‖² = P_t per
//! device per round and the Eq. 6 audit is unchanged in meaning.
//!
//! The trainer stays scheme-agnostic through the replica hooks on
//! [`LinkScheme`]: [`LinkScheme::replicas`] exposes the per-device models
//! for gradient evaluation and [`LinkScheme::replica_average`] the
//! consensus model whose accuracy the log reports; telemetry adds the
//! root-mean-square consensus distance every round.

use crate::analog::{AnalogDevice, AnalogPs};
use crate::campaign::snapshot::{self, SnapshotError, SnapshotReader, SnapshotWriter};
use crate::channel::{FadingProcess, PowerMeter};
use crate::config::RunConfig;
use crate::optim::{Adam, Optimizer};
use crate::tensor::Matf;
use crate::topology::{Graph, MixingMatrix};
use crate::util::rng::Pcg64;

use super::super::device::DeviceSet;
use super::analog::{analog_parts, post_sparsify_norm, pre_sparsify_norm};
use super::diag::{DiagSink, RoundDiagnostics};
use super::{LinkRound, LinkScheme, RoundCtx, RoundTelemetry};

pub struct D2dAnalogLink {
    devices: DeviceSet<AnalogDevice>,
    graph: Graph,
    mixing: MixingMatrix,
    /// Per-device model replicas (row i = θ_i), all starting at θ_0 = 0.
    replicas: Matf,
    /// Per-device local optimizers (same Adam the star PS runs).
    optimizers: Vec<Adam>,
    ps_std: AnalogPs,
    ps_mr: Option<AnalogPs>,
    mean_removal_rounds: usize,
    channel_uses: usize,
    /// Per-edge gain process keyed by the canonical unordered pair id.
    edge_gains: FadingProcess,
    /// Shared broadcast noise stream — same constants as the star MAC
    /// (`GaussianMac::new(.., seed ^ 0xC4A)` with stream 0x3AC), which the
    /// fully-connected degeneracy golden requires.
    noise_rng: Pcg64,
    noise_var: f64,
    meter: PowerMeter,
    dim: usize,
    diag: Option<DiagSink>,
}

impl D2dAnalogLink {
    pub fn new(cfg: &RunConfig, dim: usize) -> D2dAnalogLink {
        Self::build(cfg, dim, None)
    }

    /// Explicit worker count for the encode fan-out (`1` forces the
    /// sequential path; determinism tests prove pool-size invariance).
    pub fn with_workers(cfg: &RunConfig, dim: usize, workers: usize) -> D2dAnalogLink {
        Self::build(cfg, dim, Some(workers))
    }

    fn build(cfg: &RunConfig, dim: usize, workers: Option<usize>) -> D2dAnalogLink {
        // Same projection/noise seed recipe as the static link — the
        // degeneracy golden needs lockstep forever.
        let (states, _mac, ps_std, ps_mr) = analog_parts(cfg, dim);
        let devices = match workers {
            Some(w) => DeviceSet::with_workers(states, w),
            None => DeviceSet::new(states),
        };
        let graph = Graph::build(&cfg.topology, cfg.devices, cfg.seed ^ 0xD2D0);
        let mixing = MixingMatrix::build(&graph, cfg.topology.mixing);
        D2dAnalogLink {
            devices,
            graph,
            mixing,
            replicas: Matf::zeros(cfg.devices, dim),
            optimizers: (0..cfg.devices).map(|_| Adam::new(dim, cfg.lr as f32)).collect(),
            ps_std,
            ps_mr,
            mean_removal_rounds: cfg.mean_removal_rounds,
            channel_uses: cfg.channel_uses,
            edge_gains: FadingProcess::with_rho(cfg.fading, cfg.seed ^ 0xD2D1, cfg.fading_rho),
            noise_rng: Pcg64::with_stream(cfg.seed ^ 0xC4A, 0x3AC),
            noise_var: cfg.noise_var,
            meter: PowerMeter::new(cfg.devices),
            dim,
            diag: None,
        }
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn mixing(&self) -> &MixingMatrix {
        &self.mixing
    }

    /// √((1/M)Σ_i‖θ_i − θ̄‖²), f64-accumulated.
    pub fn consensus_distance(&self) -> f64 {
        let m = self.replicas.rows;
        let d = self.replicas.cols;
        let mut mean = vec![0.0f64; d];
        for i in 0..m {
            for (acc, &v) in mean.iter_mut().zip(self.replicas.row(i)) {
                *acc += v as f64;
            }
        }
        for v in mean.iter_mut() {
            *v /= m as f64;
        }
        let mut total = 0.0f64;
        for i in 0..m {
            for (&mu, &v) in mean.iter().zip(self.replicas.row(i)) {
                let diff = v as f64 - mu;
                total += diff * diff;
            }
        }
        (total / m as f64).sqrt()
    }

    /// The per-edge gain for transmitter j heard at receiver i (h_ii = 1:
    /// a device's own frame is folded in digitally, not over the air).
    fn gain(&self, receiver: usize, transmitter: usize, t: usize) -> f64 {
        if receiver == transmitter {
            1.0
        } else {
            self.edge_gains
                .gain(self.graph.pair_id(receiver, transmitter) as usize, t)
        }
    }
}

impl LinkScheme for D2dAnalogLink {
    fn round(&mut self, ctx: &RoundCtx, grads: &Matf) -> LinkRound {
        let m = self.devices.len();
        let d = self.dim;
        debug_assert_eq!(grads.rows, m);
        let mean_removal = ctx.t < self.mean_removal_rounds;
        let s = self.channel_uses;
        let p_t = ctx.p_t;

        // Probe prologue: ‖g + Δ(t)‖ per device, read before encode mutates
        // the accumulators. Only runs while a sink is installed.
        let pre_norms: Option<Vec<f64>> = self.diag.as_ref().map(|_| {
            self.devices
                .iter()
                .enumerate()
                .map(|(dev, state)| pre_sparsify_norm(grads.row(dev), state.accumulator()))
                .collect()
        });

        // 1. Encode: identical closure to the static AnalogLink (blind
        // full-power frames, no per-receiver scaling possible).
        let frames: Vec<Vec<f32>> = {
            let _sp = crate::util::prof::span("encode");
            if mean_removal {
                let proj = self
                    .ps_mr
                    .as_ref()
                    .expect("mean-removal decoder")
                    .projection();
                self.devices.encode(|dev, state| {
                    state
                        .transmit_mean_removed(grads.row(dev), proj, p_t, s)
                        .x
                })
            } else {
                let proj = self.ps_std.projection();
                self.devices
                    .encode(|dev, state| state.transmit(grads.row(dev), proj, p_t).x)
            }
        };
        // One f64 energy per frame: the meter records exactly these values
        // in exactly this order (hoisted so the probe can reuse them
        // without re-deriving).
        let energies: Vec<f64> = frames.iter().map(|x| crate::tensor::norm_sq(x)).collect();
        for (dev, &e) in energies.iter().enumerate() {
            self.meter.add(dev, e);
        }
        self.meter.end_round();

        // 2. Shared broadcast noise draw (star-MAC RNG stream).
        let sd = self.noise_var.sqrt();
        let z: Vec<f32> = (0..s).map(|_| (self.noise_rng.normal() * sd) as f32).collect();

        // Per-receiver superposition + blind decode. Only with unit edge
        // gains does y_i depend solely on the closed neighborhood (the
        // receiver's own frame always enters at gain 1, so any constant
        // c ≠ 1 still weights self vs neighbors differently per receiver);
        // in that case receivers sharing a neighborhood share one decode —
        // the complete graph decodes exactly once.
        let unit_gains = matches!(
            self.edge_gains.dist(),
            crate::config::FadingDist::Constant(c) if c == 1.0
        );
        let decoder = if mean_removal {
            self.ps_mr.as_ref().expect("mean-removal decoder")
        } else {
            &self.ps_std
        };
        let mut cache: std::collections::BTreeMap<Vec<usize>, usize> =
            std::collections::BTreeMap::new();
        let mut decoded: Vec<(Vec<f32>, usize)> = Vec::new();
        let mut residuals: Vec<Option<f64>> = Vec::new();
        let mut ghat_index = vec![0usize; m];
        let mut tx_set_sizes = vec![0usize; m];
        for i in 0..m {
            let hood = self.graph.closed_neighborhood(i);
            tx_set_sizes[i] = hood.len();
            if unit_gains {
                if let Some(&idx) = cache.get(&hood) {
                    ghat_index[i] = idx;
                    continue;
                }
            }
            // Frames accumulate in sorted device order into a zero vector
            // and the noise lands last — the same f32 op order as
            // `GaussianMac::transmit`, so the full-neighborhood h ≡ 1 case
            // reproduces the star MAC output bit-for-bit.
            let mut y = vec![0f32; s];
            {
                let _sp = crate::util::prof::span("transmit");
                for &j in &hood {
                    let h = self.gain(i, j, ctx.t) as f32;
                    for (yi, &xi) in y.iter_mut().zip(&frames[j]) {
                        *yi += h * xi;
                    }
                }
                for (yi, &zi) in y.iter_mut().zip(&z) {
                    *yi += zi;
                }
            }
            let (ghat_i, trace) = {
                let _sp = crate::util::prof::span("decode_amp");
                if mean_removal {
                    decoder.decode_mean_removed(&y)
                } else {
                    decoder.decode(&y)
                }
            };
            let idx = decoded.len();
            decoded.push((ghat_i, trace.iterations));
            residuals.push(trace.tau.last().copied());
            if unit_gains {
                cache.insert(hood, idx);
            }
            ghat_index[i] = idx;
        }
        let amp_iterations = decoded.iter().map(|&(_, it)| it).max().unwrap_or(0);

        // 3. Consensus mixing in deviation form (bit-exact no-op when all
        // replicas agree), then the local optimizer step on ĝ_i.
        {
            let _sp = crate::util::prof::span("consensus");
            let old = self.replicas.clone();
            for i in 0..m {
                let row = self.mixing.row(i);
                let theta_i = old.row(i);
                let target = self.replicas.row_mut(i);
                for c in 0..d {
                    let mut acc = 0.0f64;
                    for &j in self.graph.neighbors(i) {
                        acc += row[j] * (old.at(j, c) - theta_i[c]) as f64;
                    }
                    target[c] = theta_i[c] + acc as f32;
                }
                self.optimizers[i].step(target, &decoded[ghat_index[i]].0);
            }
        }

        // Reported ĝ: the fleet-average decoded gradient (f64-accumulated;
        // exact when every receiver decodes the same vector, so the
        // degeneracy golden sees the star ĝ bit-for-bit).
        let mut ghat_acc = vec![0.0f64; d];
        for i in 0..m {
            for (acc, &v) in ghat_acc.iter_mut().zip(&decoded[ghat_index[i]].0) {
                *acc += v as f64;
            }
        }
        let ghat: Vec<f32> = ghat_acc.iter().map(|&v| (v / m as f64) as f32).collect();

        // Free the mean-removal projection once past its phase.
        if !mean_removal && self.ps_mr.is_some() {
            self.ps_mr = None;
        }
        let consensus = self.consensus_distance();

        if let (Some(sink), Some(pre)) = (&self.diag, &pre_norms) {
            let mut diag = RoundDiagnostics::new(ctx.t, "d2d-A-DSGD", m);
            let mut max_energy: f64 = 0.0;
            // Mean per-receiver received signal energy, Σ_{j∈N̄(i)} h²‖x_j‖²
            // (edge-gain reads are counter-based and pure — no RNG state
            // advances here).
            let mut received_mean = 0.0f64;
            for (i, state) in self.devices.iter().enumerate() {
                let acc = state.accumulator_norm();
                let dd = &mut diag.devices[i];
                dd.pre_sparsify_norm = pre[i];
                dd.post_sparsify_norm = post_sparsify_norm(pre[i], acc);
                dd.accumulator_norm = acc;
                dd.tx_energy = energies[i];
                // Satellite: per-receiver transmit-set size (closed
                // neighborhood — everyone this receiver heard, incl. self).
                dd.d2d_tx_set = Some(tx_set_sizes[i]);
                max_energy = max_energy.max(energies[i]);
                let mut received_i = 0.0f64;
                for &j in &self.graph.closed_neighborhood(i) {
                    let h = self.gain(i, j, ctx.t);
                    received_i += h * h * energies[j];
                }
                received_mean += received_i / m as f64;
            }
            diag.power_budget = p_t;
            diag.power_headroom = p_t - max_energy;
            diag.effective_snr_db = super::diag::snr_db(received_mean, s, self.noise_var);
            diag.amp_iterations = amp_iterations;
            // Residual of the slowest decode (the one amp_iterations counts).
            diag.amp_final_residual = decoded
                .iter()
                .enumerate()
                .max_by_key(|&(_, &(_, it))| it)
                .and_then(|(idx, _)| residuals[idx]);
            diag.consensus_distance = Some(consensus);
            sink.record(diag);
        }

        LinkRound {
            ghat,
            telemetry: RoundTelemetry {
                bits_per_device: 0.0,
                amp_iterations,
                participation: None,
                consensus_distance: Some(consensus),
            },
        }
    }

    fn accumulator_norm(&self) -> f64 {
        self.devices.mean_over(|d| d.accumulator_norm())
    }

    fn measured_avg_power(&self) -> Vec<f64> {
        self.meter.report(self.channel_uses).averages()
    }

    fn name(&self) -> &'static str {
        "d2d-A-DSGD"
    }

    fn probe(&mut self, sink: Option<DiagSink>) {
        self.diag = sink;
    }

    fn replicas(&self) -> Option<&Matf> {
        Some(&self.replicas)
    }

    /// Decentralized state is per device: error accumulator, model replica
    /// θ_i, and local Adam moments — plus the shared broadcast-noise RNG
    /// position and the meter. The graph, mixing matrix and per-edge gain
    /// process are config-derived (counter-based) and not stored.
    fn snapshot(&self, w: &mut SnapshotWriter) {
        let m = self.devices.len();
        w.u64(m as u64);
        for dev in self.devices.iter() {
            w.vec_f32(dev.accumulator());
        }
        for i in 0..m {
            w.vec_f32(self.replicas.row(i));
        }
        for opt in &self.optimizers {
            let (om, ov, ot) = opt.export_state();
            w.vec_f32(&om);
            w.vec_f32(&ov);
            w.u64(ot);
        }
        let st = self.noise_rng.raw_state();
        snapshot::write_rng(w, st);
        snapshot::write_meter(w, &self.meter);
    }

    fn restore(&mut self, r: &mut SnapshotReader<'_>) -> Result<(), SnapshotError> {
        let m = r.u64()? as usize;
        if m != self.devices.len() {
            return Err(SnapshotError::Corrupt(format!(
                "snapshot has {m} devices, link has {}",
                self.devices.len()
            )));
        }
        let dim = self.dim;
        let bad_len = |what: &str, got: usize| {
            SnapshotError::Corrupt(format!("{what} length {got} != model dimension {dim}"))
        };
        for dev in self.devices.iter_mut() {
            let acc = r.vec_f32()?;
            if acc.len() != dim {
                return Err(bad_len("accumulator", acc.len()));
            }
            dev.load_accumulator(&acc);
        }
        for i in 0..m {
            let row = r.vec_f32()?;
            if row.len() != dim {
                return Err(bad_len("replica", row.len()));
            }
            self.replicas.row_mut(i).copy_from_slice(&row);
        }
        for opt in self.optimizers.iter_mut() {
            let om = r.vec_f32()?;
            let ov = r.vec_f32()?;
            let ot = r.u64()?;
            if om.len() != dim || ov.len() != dim {
                return Err(bad_len("optimizer moment", om.len()));
            }
            opt.import_state(&om, &ov, ot);
        }
        let st = snapshot::read_rng(r)?;
        self.noise_rng = Pcg64::from_raw_state(st.0, st.1, st.2);
        snapshot::read_meter(r, &mut self.meter)
    }

    fn replica_average(&self) -> Option<Vec<f32>> {
        let m = self.replicas.rows;
        let mut mean = vec![0.0f64; self.replicas.cols];
        for i in 0..m {
            for (acc, &v) in mean.iter_mut().zip(self.replicas.row(i)) {
                *acc += v as f64;
            }
        }
        Some(mean.iter().map(|&v| (v / m as f64) as f32).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::super::AnalogLink;
    use super::*;
    use crate::config::{presets, FadingDist, GraphFamily, Scheme, TopologyConfig};

    fn small_cfg(family: GraphFamily) -> RunConfig {
        RunConfig {
            scheme: Scheme::D2dADsgd,
            devices: 6,
            channel_uses: 101,
            sparsity: 25,
            mean_removal_rounds: 2,
            amp_iters: 20,
            fading: FadingDist::Constant(1.0),
            topology: TopologyConfig {
                family,
                seed: 9,
                ..TopologyConfig::default()
            },
            ..presets::smoke()
        }
    }

    fn grads(m: usize, d: usize, seed: u64) -> Matf {
        let mut rng = Pcg64::new(seed);
        Matf::from_vec(
            m,
            d,
            (0..m * d).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect(),
        )
    }

    fn ctx(t: usize) -> RoundCtx {
        RoundCtx {
            t,
            p_t: 500.0,
            deadline: None,
        }
    }

    #[test]
    fn full_graph_round_matches_static_link_bit_for_bit() {
        let d = 500;
        let cfg = small_cfg(GraphFamily::Full);
        let g = grads(6, d, 11);
        let mut star = AnalogLink::new(&cfg, d);
        let mut d2d = D2dAnalogLink::new(&cfg, d);
        for t in 0..4 {
            let a = star.round(&ctx(t), &g);
            let b = d2d.round(&ctx(t), &g);
            assert_eq!(a.ghat, b.ghat, "t={t}");
            assert_eq!(
                b.telemetry.consensus_distance,
                Some(0.0),
                "lockstep replicas never disagree on the complete graph"
            );
        }
        assert_eq!(star.measured_avg_power(), d2d.measured_avg_power());
    }

    #[test]
    fn ring_replicas_diverge_but_stay_close() {
        let d = 400;
        let cfg = small_cfg(GraphFamily::Ring);
        let mut link = D2dAnalogLink::new(&cfg, d);
        let g = grads(6, d, 12);
        let mut last = 0.0;
        for t in 0..4 {
            let out = link.round(&ctx(t), &g);
            let dist = out.telemetry.consensus_distance.expect("d2d reports consensus");
            assert!(dist.is_finite());
            last = dist;
        }
        // Distinct neighborhoods decode distinct noisy averages, so the
        // replicas genuinely disagree...
        assert!(last > 0.0, "ring replicas should not be in perfect lockstep");
        // ...but mixing keeps them within a small multiple of the update
        // scale (loose sanity bound, not a convergence theorem).
        let avg = link.replica_average().unwrap();
        assert_eq!(avg.len(), d);
        assert!(last < 1.0, "consensus distance {last} exploded");
    }

    #[test]
    fn every_device_spends_exactly_pt() {
        let d = 400;
        let cfg = small_cfg(GraphFamily::Torus);
        let mut link = D2dAnalogLink::new(&cfg, d);
        let g = grads(6, d, 13);
        for t in 0..3 {
            link.round(&ctx(t), &g);
        }
        for &p in &link.measured_avg_power() {
            assert!((p - 500.0).abs() < 1e-2 * 500.0, "avg power {p}");
        }
    }

    #[test]
    fn replicas_move_and_average_is_reported() {
        let d = 300;
        let cfg = small_cfg(GraphFamily::Ring);
        let mut link = D2dAnalogLink::new(&cfg, d);
        assert_eq!(link.replicas().unwrap().rows, 6);
        assert!(link
            .replica_average()
            .unwrap()
            .iter()
            .all(|&v| v == 0.0));
        link.round(&ctx(0), &grads(6, d, 14));
        let avg = link.replica_average().unwrap();
        assert!(crate::tensor::norm(&avg) > 0.0, "replicas should move");
    }

    #[test]
    fn rayleigh_edges_decode_per_receiver() {
        // With non-constant per-edge gains the dedupe cache must not
        // collapse distinct receivers: ring receivers see different h and
        // decode different ĝ_i, so consensus distance is positive after
        // one round even though all replicas started equal.
        let d = 300;
        let cfg = RunConfig {
            fading: FadingDist::Rayleigh,
            ..small_cfg(GraphFamily::Ring)
        };
        let mut link = D2dAnalogLink::new(&cfg, d);
        let out = link.round(&ctx(0), &grads(6, d, 15));
        assert!(out.telemetry.consensus_distance.unwrap() > 0.0);
    }

    #[test]
    fn non_unit_constant_gains_decode_per_receiver() {
        // With h ≡ c ≠ 1 the receiver's own frame still enters at gain 1,
        // so even on the complete graph every receiver hears a different
        // superposition — the decode-dedup cache must not collapse them
        // (regression: the cache used to key on the neighborhood for any
        // constant distribution, silently handing receiver 0's ĝ to all).
        let d = 300;
        let cfg = RunConfig {
            fading: FadingDist::Constant(0.7),
            ..small_cfg(GraphFamily::Full)
        };
        let mut link = D2dAnalogLink::new(&cfg, d);
        let out = link.round(&ctx(0), &grads(6, d, 16));
        assert!(
            out.telemetry.consensus_distance.unwrap() > 0.0,
            "distinct per-receiver decodes must leave the replicas apart"
        );
    }

    #[test]
    fn probe_is_read_only_and_reports_neighborhoods() {
        let d = 300;
        let cfg = RunConfig {
            fading: FadingDist::Rayleigh,
            ..small_cfg(GraphFamily::Ring)
        };
        let g = grads(6, d, 17);

        let mut plain = D2dAnalogLink::new(&cfg, d);
        let mut probed = D2dAnalogLink::new(&cfg, d);
        let sink = DiagSink::new();
        probed.probe(Some(sink.clone()));

        for t in 0..3 {
            let a = plain.round(&ctx(t), &g);
            let b = probed.round(&ctx(t), &g);
            assert_eq!(a.ghat, b.ghat, "probe must not perturb the round (t={t})");
            assert_eq!(
                a.telemetry.consensus_distance,
                b.telemetry.consensus_distance
            );
        }

        let diags = sink.drain();
        assert_eq!(diags.len(), 3);
        for (t, diag) in diags.iter().enumerate() {
            assert_eq!(diag.t, t);
            assert_eq!(diag.scheme, "d2d-A-DSGD");
            assert_eq!(diag.devices.len(), 6);
            assert_eq!(diag.power_budget, 500.0);
            // Blind full-power encode spends exactly P_t (up to the
            // projection's f32 rounding), so headroom hugs zero.
            assert!(diag.power_headroom.abs() < 1e-2 * 500.0);
            assert!(diag.effective_snr_db.is_some(), "noisy link reports SNR");
            assert!(diag.amp_iterations > 0);
            assert!(diag.amp_final_residual.is_some());
            assert!(diag.consensus_distance.unwrap() > 0.0);
            for dd in &diag.devices {
                // Every ring receiver hears itself plus two neighbors.
                assert_eq!(dd.d2d_tx_set, Some(3));
                assert!((dd.tx_energy - 500.0).abs() < 1e-2 * 500.0);
                assert!(dd.pre_sparsify_norm >= dd.post_sparsify_norm);
                assert!(dd.post_sparsify_norm > 0.0);
                assert!(dd.fading_gain.is_none(), "per-edge gains have no single h_m");
            }
        }
    }

    #[test]
    fn edge_gains_are_reciprocal() {
        let cfg = small_cfg(GraphFamily::Full);
        let link = D2dAnalogLink::new(&cfg, 100);
        for t in 0..5 {
            assert_eq!(link.gain(1, 4, t), link.gain(4, 1, t));
            assert_eq!(link.gain(2, 2, t), 1.0);
        }
    }
}
