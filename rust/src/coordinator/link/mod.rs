//! The pluggable transmission pipeline: everything between "per-device
//! gradients are ready" and "the PS holds ĝ" lives behind [`LinkScheme`].
//!
//! # The encode / aggregate / audit contract
//!
//! One training round is one [`LinkScheme::round`] call:
//!
//! 1. **Encode** (device side): each device turns its gradient row into a
//!    channel frame — sparsify/project/power-scale for analog, quantize
//!    within the capacity budget for digital. Implementations fan this out
//!    through [`DeviceSet::encode`], which runs the M independent encodes
//!    on a thread pool ([`crate::util::threadpool::par_map`]); because all
//!    per-device randomness is seeded per device, the parallel path is
//!    bit-identical to a sequential one.
//! 2. **Aggregate** (PS side): the frames traverse the link's channel model
//!    (the Gaussian MAC for analog superposition; an assumed
//!    capacity-achieving code for digital) and the PS reconstructs the
//!    average gradient estimate ĝ.
//! 3. **Audit**: the link meters every device's transmit energy as it goes;
//!    [`LinkScheme::measured_avg_power`] exposes the per-device average for
//!    the Eq. 6 power-constraint check, and per-round telemetry (bits spent,
//!    AMP iterations) comes back in the [`LinkRound`].
//!
//! The trainer ([`crate::coordinator::Trainer`]) is scheme-agnostic: it
//! builds the link once via [`for_config`] and drives
//! `gradients → link.round() → optimizer` without ever matching on
//! [`Scheme`]. New scenarios — fading MACs, blind transmitters, partial
//! participation, stragglers — plug in as new `LinkScheme` implementations
//! without touching the trainer loop.
//!
//! [`DeviceSet::encode`]: crate::coordinator::device::DeviceSet::encode
//! [`Scheme`]: crate::config::Scheme

pub mod analog;
pub mod digital;
pub mod error_free;

pub use analog::AnalogLink;
pub use digital::DigitalLink;
pub use error_free::ErrorFreeLink;

use crate::config::{LinkKind, RunConfig};
use crate::tensor::Matf;

/// Everything a link may need about the current round.
#[derive(Clone, Copy, Debug)]
pub struct RoundCtx {
    /// Iteration index t (0-based).
    pub t: usize,
    /// Power allocated to this round, P_t.
    pub p_t: f64,
}

/// Per-round link telemetry surfaced into [`crate::coordinator::RoundRecord`].
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundTelemetry {
    /// Digital links: largest actual per-device payload this round
    /// (asserted ≤ the capacity budget R_t). 0 for analog/passthrough.
    pub bits_per_device: f64,
    /// Analog links: AMP decoder iterations. 0 for digital/passthrough.
    pub amp_iterations: usize,
}

/// The PS-side result of one round.
#[derive(Clone, Debug)]
pub struct LinkRound {
    /// Reconstructed average-gradient estimate ĝ (length d).
    pub ghat: Vec<f32>,
    pub telemetry: RoundTelemetry,
}

/// A transmission scheme over the shared medium: device-side encode, the
/// channel, and PS-side reconstruction, with power/telemetry accounting.
pub trait LinkScheme {
    /// Run one synchronous round over the `M × d` gradient matrix.
    fn round(&mut self, ctx: &RoundCtx, grads: &Matf) -> LinkRound;

    /// Mean ‖Δ_m‖ across devices (0 for schemes without error accumulation).
    fn accumulator_norm(&self) -> f64;

    /// Eq. 6 audit hook: measured per-device average transmit power over
    /// the rounds run so far.
    fn measured_avg_power(&self) -> Vec<f64>;

    fn name(&self) -> &'static str;
}

/// Build the link implementation serving `cfg.scheme` (the coordinator-side
/// half of the factory; [`crate::config::Scheme::kind`] is the config side).
pub fn for_config(cfg: &RunConfig, dim: usize) -> Box<dyn LinkScheme> {
    match cfg.scheme.kind() {
        LinkKind::Passthrough => Box::new(ErrorFreeLink::new(cfg.devices, dim)),
        LinkKind::Digital => Box::new(DigitalLink::new(cfg, dim)),
        LinkKind::Analog => Box::new(AnalogLink::new(cfg, dim)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Scheme};
    use crate::model::PARAM_DIM;

    #[test]
    fn factory_builds_every_scheme() {
        for (scheme, name) in [
            (Scheme::ErrorFree, "error-free"),
            (Scheme::ADsgd, "A-DSGD"),
            (Scheme::DDsgd, "digital"),
            (Scheme::SignSgd, "digital"),
            (Scheme::Qsgd, "digital"),
        ] {
            let cfg = RunConfig {
                scheme,
                // Small channel so the analog projections are cheap to build.
                channel_uses: 64,
                sparsity: 16,
                ..presets::smoke()
            };
            let link = for_config(&cfg, PARAM_DIM);
            assert_eq!(link.name(), name, "{scheme:?}");
            assert_eq!(link.measured_avg_power().len(), cfg.devices);
            assert_eq!(link.accumulator_norm(), 0.0, "fresh link, no residue");
        }
    }
}
