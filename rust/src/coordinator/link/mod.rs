//! The pluggable transmission pipeline: everything between "per-device
//! gradients are ready" and "the PS holds ĝ" lives behind [`LinkScheme`].
//!
//! # The encode / aggregate / audit contract
//!
//! One training round is one [`LinkScheme::round`] call:
//!
//! 1. **Encode** (device side): each device turns its gradient row into a
//!    channel frame — sparsify/project/power-scale for analog, quantize
//!    within the capacity budget for digital. Implementations fan this out
//!    through [`DeviceSet::encode`], which runs the M independent encodes
//!    on a thread pool ([`crate::util::threadpool::par_map`]); because all
//!    per-device randomness is seeded per device, the parallel path is
//!    bit-identical to a sequential one.
//! 2. **Aggregate** (PS side): the frames traverse the link's channel model
//!    (the Gaussian MAC for analog superposition; an assumed
//!    capacity-achieving code for digital) and the PS reconstructs the
//!    average gradient estimate ĝ.
//! 3. **Audit**: the link meters every device's transmit energy as it goes;
//!    [`LinkScheme::measured_avg_power`] exposes the per-device average for
//!    the Eq. 6 power-constraint check, and per-round telemetry (bits spent,
//!    AMP iterations, participation counts) comes back in the [`LinkRound`].
//!
//! # Variable participation and fading gains
//!
//! The original contract assumed all M devices transmit every round over a
//! static MAC. The fading links ([`FadingAnalogLink`]) generalize it:
//!
//! * **Per-round gains.** A seeded [`crate::channel::FadingProcess`] draws
//!   h_m(t) for every device each round; the channel applies them
//!   (`GaussianMac::transmit_faded`) while the power meter keeps recording
//!   the *transmitted* energy ‖x_m‖², so the Eq. 6 audit is unchanged in
//!   meaning: it binds what each device radiates.
//! * **Variable transmitting set.** A device may sit a round out for three
//!   reasons, counted separately in [`ParticipationStats`]: the
//!   participation policy did not schedule it, CSI truncated inversion
//!   silenced it (h_m(t) below the gain threshold), or it missed the round
//!   deadline ([`RoundCtx::deadline`]) under the straggler latency model. A
//!   silent device transmits nothing (zero energy) and banks its whole
//!   error-compensated gradient in its accumulator
//!   (`AnalogDevice::absorb`), so no information is lost permanently.
//! * **Aggregation contract.** ĝ is always a length-d estimate of the
//!   average gradient *of the transmitting set*; when that set is empty the
//!   link returns ĝ = 0 rather than decoding pure noise. The Eq. 6 audit
//!   averages over all rounds driven, including silent ones.
//! * **Telemetry honesty.** Links that do not model participation report
//!   `telemetry.participation = None` — *not* zero counts — so "0 devices
//!   transmitted" is never conflated with "this scheme does not track
//!   participation" (regression-tested in `rust/tests/link_properties.rs`).
//!
//! The trainer ([`crate::coordinator::Trainer`]) is scheme-agnostic: it
//! builds the link once via [`for_config`] and drives
//! `gradients → link.round() → optimizer` without ever matching on
//! [`Scheme`]. New scenarios plug in as new `LinkScheme` implementations
//! without touching the trainer loop.
//!
//! # Decentralized links (per-device replicas)
//!
//! The original contract also assumed one global model at the PS. The D2D
//! link ([`D2dAnalogLink`]) breaks that: each device holds its own model
//! replica and the "PS reconstruction" step becomes per-receiver
//! neighborhood decoding plus a consensus mixing step. Two default-`None`
//! hooks keep the trainer scheme-agnostic: [`LinkScheme::replicas`] hands
//! the trainer the M per-device models the round's gradients must be
//! evaluated at, and [`LinkScheme::replica_average`] hands back the
//! consensus model the log evaluates — when both return `None` (every
//! PS-centric link) the trainer's original single-model path runs
//! bit-for-bit.
//!
//! [`DeviceSet::encode`]: crate::coordinator::device::DeviceSet::encode
//! [`Scheme`]: crate::config::Scheme

pub mod analog;
pub mod d2d;
pub mod diag;
pub mod digital;
pub mod error_free;
pub mod fading;

pub use analog::AnalogLink;
pub use d2d::D2dAnalogLink;
pub use diag::{DeviceDiag, DeviceOutcome, DiagSink, RoundDiagnostics};
pub use digital::DigitalLink;
pub use error_free::ErrorFreeLink;
pub use fading::FadingAnalogLink;

use crate::campaign::snapshot::{SnapshotError, SnapshotReader, SnapshotWriter};
use crate::config::{LinkKind, RunConfig, Scheme};
use crate::tensor::Matf;

/// Everything a link may need about the current round.
#[derive(Clone, Copy, Debug)]
pub struct RoundCtx {
    /// Iteration index t (0-based).
    pub t: usize,
    /// Power allocated to this round, P_t.
    pub p_t: f64,
    /// Round deadline in simulated seconds; devices whose modeled encode
    /// latency exceeds it are dropped from aggregation. `None` disables
    /// straggler dropping (links without a latency model ignore it).
    pub deadline: Option<f64>,
}

/// Where the M devices went in one round of a participation-aware link.
/// The four counts always sum to M.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ParticipationStats {
    /// Devices whose frames actually hit the channel this round.
    pub transmitting: usize,
    /// Devices excluded by the round-level participation policy.
    pub not_scheduled: usize,
    /// Devices silenced by the CSI gain threshold (truncated inversion).
    pub silenced_low_gain: usize,
    /// Devices dropped for missing the round deadline.
    pub dropped_stragglers: usize,
}

impl ParticipationStats {
    /// Total devices accounted for (must equal M).
    pub fn total(&self) -> usize {
        self.transmitting + self.not_scheduled + self.silenced_low_gain + self.dropped_stragglers
    }
}

/// Per-round link telemetry surfaced into [`crate::coordinator::RoundRecord`].
///
/// Scalar fields default to 0 for schemes that don't produce them, which is
/// acceptable only because their semantics make 0 an honest value ("0 bits
/// spent", "0 AMP iterations run"). Participation counts are different — a
/// static link genuinely has M transmitting devices, not 0 — so they are
/// `Option`-typed: `None` means "this scheme does not model participation",
/// never "0 devices participated".
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundTelemetry {
    /// Digital links: largest actual per-device payload this round
    /// (asserted ≤ the capacity budget R_t). 0 for analog/passthrough.
    pub bits_per_device: f64,
    /// Analog links: AMP decoder iterations. 0 for digital/passthrough.
    pub amp_iterations: usize,
    /// Participation-aware links: where the M devices went this round.
    /// `None` for links that do not model participation.
    pub participation: Option<ParticipationStats>,
    /// Decentralized links: root-mean-square replica disagreement
    /// √((1/M)Σ‖θ_i − θ̄‖²) after the round's mixing + local steps.
    /// `None` for PS-centric links (one global model — disagreement is not
    /// a defined quantity, not a measured 0).
    pub consensus_distance: Option<f64>,
}

/// The PS-side result of one round.
#[derive(Clone, Debug)]
pub struct LinkRound {
    /// Reconstructed average-gradient estimate ĝ (length d).
    pub ghat: Vec<f32>,
    pub telemetry: RoundTelemetry,
}

/// A transmission scheme over the shared medium: device-side encode, the
/// channel, and PS-side reconstruction, with power/telemetry accounting.
pub trait LinkScheme {
    /// Run one synchronous round over the `M × d` gradient matrix.
    fn round(&mut self, ctx: &RoundCtx, grads: &Matf) -> LinkRound;

    /// Mean ‖Δ_m‖ across devices (0 for schemes without error accumulation).
    fn accumulator_norm(&self) -> f64;

    /// Eq. 6 audit hook: measured per-device average transmit power over
    /// the rounds run so far.
    fn measured_avg_power(&self) -> Vec<f64>;

    fn name(&self) -> &'static str;

    /// Install (or remove, with `None`) an observe-only diagnostics sink.
    /// While a sink is installed the link records one
    /// [`RoundDiagnostics`] per [`LinkScheme::round`] call; with no sink
    /// (the default) nothing extra is computed. Implementations must keep
    /// probing strictly read-only — no RNG draws, no change to any f32
    /// operation order — so trajectories are byte-identical with probes on
    /// or off. Default is a no-op so third-party links stay source
    /// compatible; every factory scheme implements it.
    fn probe(&mut self, _sink: Option<DiagSink>) {}

    /// Decentralized links: the M per-device model replicas the round's
    /// gradients must be evaluated at (row m = device m's θ). `None` for
    /// PS-centric links, where every device shares the PS model — the
    /// trainer then keeps its original single-model path bit-for-bit.
    fn replicas(&self) -> Option<&Matf> {
        None
    }

    /// Decentralized links: the replica-average model θ̄ (f64-accumulated),
    /// which the trainer adopts as the evaluation model after each round —
    /// replica links apply their own mixing + local optimizer steps inside
    /// [`LinkScheme::round`], so the PS optimizer must not also step.
    fn replica_average(&self) -> Option<Vec<f32>> {
        None
    }

    /// Checkpoint hook: serialize every piece of state that evolves across
    /// rounds — error accumulators, advancing RNG positions (MAC noise,
    /// QSGD rounding, D2D broadcast noise), power-meter totals, model
    /// replicas and their local optimizers. Anything *not* written here
    /// must be reconstructible from the `RunConfig` alone (projections,
    /// graphs, counter-based generators), because restore happens on a
    /// freshly built link. Deliberately a required method: a new scheme
    /// that forgets its round state would silently break bit-identical
    /// resume, so the compiler makes the author decide.
    fn snapshot(&self, w: &mut SnapshotWriter);

    /// Restore state written by [`LinkScheme::snapshot`] into a freshly
    /// built link for the same config. After this, driving the remaining
    /// rounds is bit-identical to never having stopped (pinned by
    /// `rust/tests/campaign_resume.rs` for every factory scheme).
    fn restore(&mut self, r: &mut SnapshotReader<'_>) -> Result<(), SnapshotError>;
}

/// Build the link implementation serving `cfg.scheme` (the coordinator-side
/// half of the factory; [`crate::config::Scheme::kind`] is the config side).
pub fn for_config(cfg: &RunConfig, dim: usize) -> Box<dyn LinkScheme> {
    match cfg.scheme.kind() {
        LinkKind::Passthrough => Box::new(ErrorFreeLink::new(cfg.devices, dim)),
        LinkKind::Digital => Box::new(DigitalLink::new(cfg, dim)),
        LinkKind::Analog => Box::new(AnalogLink::new(cfg, dim)),
        LinkKind::Fading => {
            let csi = cfg.scheme == Scheme::FadingADsgd;
            Box::new(FadingAnalogLink::new(cfg, dim, csi))
        }
        LinkKind::D2d => Box::new(D2dAnalogLink::new(cfg, dim)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Scheme};
    use crate::model::PARAM_DIM;

    #[test]
    fn factory_builds_every_scheme() {
        for (scheme, name) in [
            (Scheme::ErrorFree, "error-free"),
            (Scheme::ADsgd, "A-DSGD"),
            (Scheme::FadingADsgd, "fading-A-DSGD"),
            (Scheme::BlindADsgd, "blind-A-DSGD"),
            (Scheme::D2dADsgd, "d2d-A-DSGD"),
            (Scheme::DDsgd, "digital"),
            (Scheme::SignSgd, "digital"),
            (Scheme::Qsgd, "digital"),
        ] {
            let cfg = RunConfig {
                scheme,
                // Small channel so the analog projections are cheap to build.
                channel_uses: 64,
                sparsity: 16,
                ..presets::smoke()
            };
            let link = for_config(&cfg, PARAM_DIM);
            assert_eq!(link.name(), name, "{scheme:?}");
            assert_eq!(link.measured_avg_power().len(), cfg.devices);
            assert_eq!(link.accumulator_norm(), 0.0, "fresh link, no residue");
        }
    }
}
