//! A-DSGD over a *fading* MAC: per-device, per-round channel gains h_m(t),
//! partial participation, and straggler deadlines on top of the Algorithm-1
//! analog pipeline.
//!
//! Two variants share this implementation:
//!
//! * **CSI (truncated channel inversion)** — each scheduled device with
//!   h_m(t) strictly above the gain threshold pre-scales its frame by
//!   ρ_t/h_m(t), where
//!   ρ_t = min over the transmitting set of h_m(t) (the largest common
//!   received amplitude that keeps every device within the P_t budget; the
//!   PS knows the CSI and broadcasts ρ_t). The channel multiplies by
//!   h_m(t), so every surviving frame arrives scaled by the *same* ρ_t and
//!   the superposition is coherent; the PS-side normalization by the last
//!   channel use (Σ ρ_t·√α_m) cancels ρ_t, so the static decoder is reused
//!   unchanged. Devices below the threshold stay silent — deep fades are
//!   truncated instead of inverted at unbounded power ("Federated Learning
//!   over Wireless Fading Channels", Amiri & Gündüz 2019).
//! * **Blind (no CSI)** — devices transmit their frames unscaled at full
//!   power P_t; the received superposition is the h_m(t)-weighted sum, and
//!   the last channel use carries Σ h_m·√α_m — exactly the normalizer the
//!   decoder divides by, so ĝ estimates the gain-weighted average gradient
//!   (Amiri, Duman & Gündüz 2019).
//!
//! With h ≡ 1 and full participation both variants reproduce
//! [`AnalogLink`](super::AnalogLink) bit for bit: same projection seeds,
//! same MAC noise stream, and every extra scaling is a multiplication by
//! `1.0f32` (exact). `rust/tests/golden_schemes.rs` pins this.
//!
//! A silent device (not scheduled, below the gain threshold, or past the
//! deadline) banks its whole error-compensated gradient via
//! [`AnalogDevice::absorb`] and spends zero transmit energy.

use crate::analog::{AnalogDevice, AnalogPs};
use crate::campaign::snapshot::{SnapshotError, SnapshotReader, SnapshotWriter};
use crate::channel::{FadingProcess, GaussianMac, LatencyModel};
use crate::config::RunConfig;
use crate::tensor::Matf;

use super::super::device::DeviceSet;
use super::super::participation::ParticipationSelector;
use super::analog::{analog_parts, restore_analog_state, snapshot_analog_state};
use super::analog::{post_sparsify_norm, pre_sparsify_norm};
use super::diag::{DeviceOutcome, DiagSink, RoundDiagnostics};
use super::{LinkRound, LinkScheme, ParticipationStats, RoundCtx, RoundTelemetry};

pub struct FadingAnalogLink {
    /// CSI at the transmitters (truncated inversion) vs blind full-power.
    csi: bool,
    devices: DeviceSet<AnalogDevice>,
    mac: GaussianMac,
    ps_std: AnalogPs,
    ps_mr: Option<AnalogPs>,
    mean_removal_rounds: usize,
    channel_uses: usize,
    fading: FadingProcess,
    selector: ParticipationSelector,
    latency: LatencyModel,
    csi_threshold: f64,
    dim: usize,
    diag: Option<DiagSink>,
}

impl FadingAnalogLink {
    pub fn new(cfg: &RunConfig, dim: usize, csi: bool) -> FadingAnalogLink {
        Self::build(cfg, dim, csi, None)
    }

    /// Explicit worker count for the encode fan-out (`1` forces the
    /// sequential path; the determinism tests use this to prove the fading
    /// pipeline is thread-pool-size invariant).
    pub fn with_workers(cfg: &RunConfig, dim: usize, csi: bool, workers: usize) -> FadingAnalogLink {
        Self::build(cfg, dim, csi, Some(workers))
    }

    fn build(cfg: &RunConfig, dim: usize, csi: bool, workers: Option<usize>) -> FadingAnalogLink {
        // Shared recipe with `AnalogLink` (same projection / MAC seed
        // constants) — the h ≡ 1 degeneracy golden depends on lockstep.
        let (states, mac, ps_std, ps_mr) = analog_parts(cfg, dim);
        let devices = match workers {
            Some(w) => DeviceSet::with_workers(states, w),
            None => DeviceSet::new(states),
        };
        FadingAnalogLink {
            csi,
            devices,
            mac,
            ps_std,
            ps_mr,
            mean_removal_rounds: cfg.mean_removal_rounds,
            channel_uses: cfg.channel_uses,
            // rho = 0 (the default) takes the i.i.d. draw path bit-for-bit.
            fading: FadingProcess::with_rho(cfg.fading, cfg.seed ^ 0xFAD1, cfg.fading_rho),
            selector: ParticipationSelector::new(cfg.participation, cfg.seed ^ 0x5E1),
            latency: LatencyModel::new(cfg.latency_mean_secs, cfg.seed ^ 0x1A7),
            csi_threshold: cfg.csi_threshold,
            dim,
            diag: None,
        }
    }

    /// Classify every device for this round. Returns (active mask, stats,
    /// per-device outcome). The outcome vector is the *reason* record the
    /// diagnostics probe reports — derived in the same pass, same
    /// conditions, same order as the mask and counts, so the three can
    /// never disagree.
    fn roll_call(
        &self,
        ctx: &RoundCtx,
        gains: &[f64],
    ) -> (Vec<bool>, ParticipationStats, Vec<DeviceOutcome>) {
        let scheduled = self.selector.select(ctx.t, gains);
        let mut active = vec![false; gains.len()];
        let mut outcomes = Vec::with_capacity(gains.len());
        let mut stats = ParticipationStats::default();
        for (dev, &h) in gains.iter().enumerate() {
            let outcome = if !scheduled[dev] {
                stats.not_scheduled += 1;
                DeviceOutcome::NotScheduled
            } else if self.csi && h <= self.csi_threshold {
                // `<=` (not `<`): with a zero threshold an exactly-zero
                // gain must still be silenced, or the inversion scale
                // ρ_t/h_m would be 0/0 = NaN. Active CSI devices therefore
                // always have h > threshold ≥ 0, so ρ_t/h_m is finite.
                stats.silenced_low_gain += 1;
                DeviceOutcome::SilencedLowGain
            } else if ctx
                .deadline
                .is_some_and(|dl| self.latency.latency(dev, ctx.t) > dl)
            {
                stats.dropped_stragglers += 1;
                DeviceOutcome::DroppedStraggler
            } else {
                active[dev] = true;
                stats.transmitting += 1;
                DeviceOutcome::Transmitting
            };
            outcomes.push(outcome);
        }
        (active, stats, outcomes)
    }
}

impl LinkScheme for FadingAnalogLink {
    fn round(&mut self, ctx: &RoundCtx, grads: &Matf) -> LinkRound {
        let m = self.devices.len();
        debug_assert_eq!(grads.rows, m);
        let gains = self.fading.gains_for_round(m, ctx.t);
        let (active, stats, outcomes) = self.roll_call(ctx, &gains);
        // Probe prologue: ‖g + Δ(t)‖ per device before encode mutates the
        // accumulators (silent devices bank g + Δ, so pre-norms are
        // meaningful for every outcome). Only runs while a sink is
        // installed.
        let pre_norms: Option<Vec<f64>> = self.diag.as_ref().map(|_| {
            self.devices
                .iter()
                .enumerate()
                .map(|(dev, state)| pre_sparsify_norm(grads.row(dev), state.accumulator()))
                .collect()
        });

        // Truncated inversion: every transmitting device pre-scales by
        // ρ_t/h_m so the channel delivers a coherent ρ_t-scaled sum; ρ_t is
        // the minimum transmitting gain, which maxes the common received
        // amplitude while keeping ‖x_m‖² = (ρ_t/h_m)²·P_t ≤ P_t for all.
        // Blind devices transmit unscaled (scale 1) at exactly P_t.
        let rho = if self.csi {
            gains
                .iter()
                .zip(&active)
                .filter(|&(_, &a)| a)
                .map(|(&h, _)| h)
                .fold(f64::INFINITY, f64::min)
        } else {
            1.0
        };
        let scales: Vec<f32> = gains
            .iter()
            .zip(&active)
            .map(|(&h, &a)| {
                if a && self.csi {
                    (rho / h) as f32
                } else {
                    1.0
                }
            })
            .collect();

        let mean_removal = ctx.t < self.mean_removal_rounds;
        let s = self.channel_uses;
        let p_t = ctx.p_t;
        let proj = if mean_removal {
            self.ps_mr
                .as_ref()
                .expect("mean-removal decoder")
                .projection()
        } else {
            self.ps_std.projection()
        };
        let active_ref = &active;
        let scales_ref = &scales;
        let frames: Vec<Option<Vec<f32>>> = {
            let _sp = crate::util::prof::span("encode");
            self.devices.encode(|dev, state| {
                if !active_ref[dev] {
                    state.absorb(grads.row(dev));
                    return None;
                }
                let mut x = if mean_removal {
                    state
                        .transmit_mean_removed(grads.row(dev), proj, p_t, s)
                        .x
                } else {
                    state.transmit(grads.row(dev), proj, p_t).x
                };
                let scale = scales_ref[dev];
                if scale != 1.0 {
                    for v in x.iter_mut() {
                        *v *= scale;
                    }
                }
                Some(x)
            })
        };
        let inputs: Vec<Vec<f32>> = frames
            .into_iter()
            .map(|f| f.unwrap_or_else(|| vec![0.0f32; s]))
            .collect();

        let y = {
            let _sp = crate::util::prof::span("transmit");
            self.mac.transmit_faded(&inputs, &gains)
        };

        // With nobody transmitting, y is pure noise — decoding it would
        // amplify garbage through the 1/y_s normalization. Return ĝ = 0.
        let _decode_sp = crate::util::prof::span("decode_amp");
        let (ghat, amp_iterations, amp_residual) = if stats.transmitting == 0 {
            (vec![0.0f32; self.dim], 0, None)
        } else if mean_removal {
            let (g, trace) = self
                .ps_mr
                .as_ref()
                .expect("mean-removal decoder")
                .decode_mean_removed(&y);
            (g, trace.iterations, trace.tau.last().copied())
        } else {
            let (g, trace) = self.ps_std.decode(&y);
            (g, trace.iterations, trace.tau.last().copied())
        };
        drop(_decode_sp);

        if let (Some(sink), Some(pre)) = (&self.diag, &pre_norms) {
            let mut d = RoundDiagnostics::new(ctx.t, self.name(), m);
            let mut received = 0.0;
            let mut max_energy: f64 = 0.0;
            for (dev, state) in self.devices.iter().enumerate() {
                let energy = if active[dev] {
                    crate::tensor::norm_sq(&inputs[dev])
                } else {
                    0.0
                };
                let acc = state.accumulator_norm();
                let dd = &mut d.devices[dev];
                dd.pre_sparsify_norm = pre[dev];
                // A silent device banks everything: nothing survived
                // sparsification because sparsification never ran.
                dd.post_sparsify_norm = if active[dev] {
                    post_sparsify_norm(pre[dev], acc)
                } else {
                    0.0
                };
                dd.accumulator_norm = acc;
                dd.fading_gain = Some(gains[dev]);
                dd.tx_energy = energy;
                dd.outcome = outcomes[dev];
                // The channel multiplies device m's frame by h_m, so the
                // received signal energy sums h²·‖x‖².
                received += gains[dev] * gains[dev] * energy;
                max_energy = max_energy.max(energy);
            }
            d.power_budget = p_t;
            d.power_headroom = p_t - max_energy;
            d.effective_snr_db = super::diag::snr_db(received, s, self.mac.noise_var);
            d.amp_iterations = amp_iterations;
            d.amp_final_residual = amp_residual;
            sink.record(d);
        }

        // Free the mean-removal projection once past its phase.
        if !mean_removal && self.ps_mr.is_some() {
            self.ps_mr = None;
        }
        LinkRound {
            ghat,
            telemetry: RoundTelemetry {
                bits_per_device: 0.0,
                amp_iterations,
                participation: Some(stats),
                consensus_distance: None,
            },
        }
    }

    fn accumulator_norm(&self) -> f64 {
        self.devices.mean_over(|d| d.accumulator_norm())
    }

    fn measured_avg_power(&self) -> Vec<f64> {
        self.mac.power_report().averages()
    }

    fn name(&self) -> &'static str {
        if self.csi {
            "fading-A-DSGD"
        } else {
            "blind-A-DSGD"
        }
    }

    fn probe(&mut self, sink: Option<DiagSink>) {
        self.diag = sink;
    }

    /// Same shape as the static analog link: accumulators + MAC state. The
    /// fading gains, participation subsets, AR(1) chains and straggler
    /// latencies are all counter-based — pure per `(seed, device, t)` — so
    /// they need no storage to resume exactly.
    fn snapshot(&self, w: &mut SnapshotWriter) {
        snapshot_analog_state(w, &self.devices, &self.mac);
    }

    fn restore(&mut self, r: &mut SnapshotReader<'_>) -> Result<(), SnapshotError> {
        restore_analog_state(r, &mut self.devices, &mut self.mac)
    }
}

#[cfg(test)]
mod tests {
    use super::super::AnalogLink;
    use super::*;
    use crate::config::{presets, FadingDist, ParticipationPolicy, Scheme};
    use crate::util::rng::Pcg64;

    fn small_cfg() -> RunConfig {
        RunConfig {
            scheme: Scheme::FadingADsgd,
            devices: 6,
            channel_uses: 101,
            sparsity: 25,
            mean_removal_rounds: 2,
            amp_iters: 30,
            ..presets::smoke()
        }
    }

    fn grads(m: usize, d: usize, seed: u64) -> Matf {
        let mut rng = Pcg64::new(seed);
        Matf::from_vec(
            m,
            d,
            (0..m * d).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect(),
        )
    }

    fn ctx(t: usize, p_t: f64) -> RoundCtx {
        RoundCtx {
            t,
            p_t,
            deadline: None,
        }
    }

    #[test]
    fn unit_gain_full_participation_matches_static_link() {
        let d = 500;
        let cfg = RunConfig {
            fading: FadingDist::Constant(1.0),
            csi_threshold: 0.5,
            ..small_cfg()
        };
        let g = grads(6, d, 11);
        for csi in [true, false] {
            let mut stat = AnalogLink::new(&cfg, d);
            let mut fad = FadingAnalogLink::new(&cfg, d, csi);
            for t in 0..4 {
                let a = stat.round(&ctx(t, 500.0), &g);
                let b = fad.round(&ctx(t, 500.0), &g);
                assert_eq!(a.ghat, b.ghat, "csi={csi} t={t}");
                assert_eq!(
                    b.telemetry.participation,
                    Some(ParticipationStats {
                        transmitting: 6,
                        ..Default::default()
                    })
                );
            }
            assert_eq!(stat.measured_avg_power(), fad.measured_avg_power());
        }
    }

    #[test]
    fn csi_threshold_silences_deep_fades() {
        let d = 400;
        let cfg = RunConfig {
            // Half the support below the threshold on average.
            fading: FadingDist::Uniform(0.0, 1.0),
            csi_threshold: 0.5,
            ..small_cfg()
        };
        let mut link = FadingAnalogLink::new(&cfg, d, true);
        let g = grads(6, d, 12);
        let mut silenced_total = 0;
        for t in 0..6 {
            let out = link.round(&ctx(t, 500.0), &g);
            let stats = out.telemetry.participation.expect("fading reports stats");
            assert_eq!(stats.total(), 6, "counts partition the fleet");
            silenced_total += stats.silenced_low_gain;
            assert_eq!(out.ghat.len(), d);
        }
        assert!(silenced_total > 0, "uniform gains under 0.5 must silence someone");
        // Transmit power never exceeds P_t per round (scale ≤ 1; 1e-4
        // slack for f32 frame rounding).
        for &p in &link.measured_avg_power() {
            assert!(p <= 500.0 * (1.0 + 1e-4), "avg power {p}");
        }
    }

    #[test]
    fn blind_ignores_csi_threshold() {
        let d = 400;
        let cfg = RunConfig {
            fading: FadingDist::Uniform(0.0, 1.0),
            csi_threshold: 0.9,
            ..small_cfg()
        };
        let mut link = FadingAnalogLink::new(&cfg, d, false);
        let out = link.round(&ctx(0, 500.0), &grads(6, d, 13));
        let stats = out.telemetry.participation.unwrap();
        assert_eq!(stats.silenced_low_gain, 0);
        assert_eq!(stats.transmitting, 6);
        // Blind devices spend exactly P_t.
        for &p in &link.measured_avg_power() {
            assert!((p - 500.0).abs() < 1e-2 * 500.0, "avg power {p}");
        }
    }

    #[test]
    fn impossible_deadline_drops_everyone_and_returns_zero() {
        let d = 400;
        let cfg = RunConfig {
            latency_mean_secs: 1.0,
            ..small_cfg()
        };
        let mut link = FadingAnalogLink::new(&cfg, d, true);
        let out = link.round(
            &RoundCtx {
                t: 0,
                p_t: 500.0,
                deadline: Some(1e-12),
            },
            &grads(6, d, 14),
        );
        let stats = out.telemetry.participation.unwrap();
        assert_eq!(stats.transmitting, 0);
        assert_eq!(stats.dropped_stragglers, 6);
        assert!(out.ghat.iter().all(|&v| v == 0.0));
        assert_eq!(out.telemetry.amp_iterations, 0);
        // Nobody transmitted, so nobody spent energy.
        assert_eq!(link.measured_avg_power(), vec![0.0; 6]);
        // The silent round still banked gradients in the accumulators.
        assert!(link.accumulator_norm() > 0.0);
    }

    #[test]
    fn probe_reports_outcomes_gains_and_headroom() {
        let d = 400;
        let cfg = RunConfig {
            fading: FadingDist::Uniform(0.0, 1.0),
            csi_threshold: 0.5,
            ..small_cfg()
        };
        let g = grads(6, d, 31);
        let run = |probe: bool| {
            let mut link = FadingAnalogLink::new(&cfg, d, true);
            let sink = DiagSink::new();
            if probe {
                link.probe(Some(sink.clone()));
            }
            let mut ghats = Vec::new();
            for t in 0..4 {
                ghats.push(link.round(&ctx(t, 500.0), &g).ghat);
            }
            (ghats, sink.drain())
        };
        let (ghat_off, _) = run(false);
        let (ghat_on, diags) = run(true);
        assert_eq!(ghat_off, ghat_on, "probes must not perturb the trajectory");
        assert_eq!(diags.len(), 4);
        for diag in &diags {
            assert_eq!(diag.scheme, "fading-A-DSGD");
            let (tx, ns, sil, dr) = diag.participation_counts();
            assert_eq!(tx + ns + sil + dr, 6, "outcomes partition the fleet");
            for dd in &diag.devices {
                let h = dd.fading_gain.expect("fading link reports h_m(t)");
                match dd.outcome {
                    DeviceOutcome::SilencedLowGain => {
                        assert!(h <= 0.5, "silenced device with h={h}");
                        assert_eq!(dd.tx_energy, 0.0);
                        assert_eq!(dd.post_sparsify_norm, 0.0);
                        // A silent round banks everything: Δ(t+1) ≥ ‖g‖-ish.
                        assert!(dd.accumulator_norm > 0.0);
                    }
                    DeviceOutcome::Transmitting => {
                        assert!(h > 0.5, "transmitting device with h={h}");
                        // Truncated inversion keeps ‖x‖² ≤ P_t.
                        assert!(dd.tx_energy > 0.0);
                        assert!(dd.tx_energy <= 500.0 * (1.0 + 1e-4));
                    }
                    _ => {}
                }
            }
            // Headroom is the budget minus the hungriest device.
            assert!(diag.power_headroom >= -500.0 * 1e-4);
            if tx > 0 {
                assert!(diag.effective_snr_db.is_some());
            }
        }
    }

    #[test]
    fn uniform_k_schedules_exactly_k() {
        let d = 400;
        let cfg = RunConfig {
            participation: ParticipationPolicy::UniformK(2),
            fading: FadingDist::Constant(1.0),
            ..small_cfg()
        };
        let mut link = FadingAnalogLink::new(&cfg, d, true);
        let g = grads(6, d, 15);
        for t in 0..4 {
            let out = link.round(&ctx(t, 500.0), &g);
            let stats = out.telemetry.participation.unwrap();
            assert_eq!(stats.transmitting, 2, "t={t}");
            assert_eq!(stats.not_scheduled, 4, "t={t}");
        }
    }
}
