//! Observe-only link diagnostics: what every scheme can tell you about a
//! round beyond the ĝ it returns.
//!
//! A [`DiagSink`] is installed through the default-no-op
//! [`LinkScheme::probe`] hook. Every scheme computes a
//! [`RoundDiagnostics`] per round **only while a sink is installed**, and
//! computes it strictly read-only: extra f64 norms over buffers the round
//! already produced, no new RNG draws, no change to any f32 operation
//! order. That construction — not a test — is why the golden trajectories
//! and `summary.csv` are byte-identical with probes on or off; the tests
//! in `rust/tests/link_diagnostics.rs` merely pin it.
//!
//! Diagnostics never enter a run's content-address and are never
//! snapshotted: a resumed link simply starts probing again from the resume
//! round. Wall-clock timing lives in [`crate::util::prof`], not here —
//! everything in this module is deterministic per `(config, seed, t)`.
//!
//! [`LinkScheme::probe`]: super::LinkScheme::probe

use std::sync::{Arc, Mutex};

/// Why a device did or did not reach the channel this round. Mirrors the
/// classification order of `FadingAnalogLink::roll_call`; the numeric
/// codes are the wire encoding used by `device` telemetry events.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceOutcome {
    /// The device's frame hit the channel.
    Transmitting,
    /// Excluded by the round-level participation policy.
    NotScheduled,
    /// Silenced by the CSI gain threshold (truncated channel inversion).
    SilencedLowGain,
    /// Dropped for missing the round deadline.
    DroppedStraggler,
}

impl DeviceOutcome {
    /// Stable numeric code for event payloads (payloads are f64-only).
    pub fn code(&self) -> u8 {
        match self {
            DeviceOutcome::Transmitting => 0,
            DeviceOutcome::NotScheduled => 1,
            DeviceOutcome::SilencedLowGain => 2,
            DeviceOutcome::DroppedStraggler => 3,
        }
    }

    /// Decode a wire code (`None` for codes this build does not know).
    pub fn from_code(code: u8) -> Option<DeviceOutcome> {
        match code {
            0 => Some(DeviceOutcome::Transmitting),
            1 => Some(DeviceOutcome::NotScheduled),
            2 => Some(DeviceOutcome::SilencedLowGain),
            3 => Some(DeviceOutcome::DroppedStraggler),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeviceOutcome::Transmitting => "transmitting",
            DeviceOutcome::NotScheduled => "not-scheduled",
            DeviceOutcome::SilencedLowGain => "silenced-low-gain",
            DeviceOutcome::DroppedStraggler => "dropped-straggler",
        }
    }
}

/// One device's view of one round.
#[derive(Clone, Debug)]
pub struct DeviceDiag {
    /// Device index m (0-based).
    pub device: usize,
    /// ‖g_m + Δ_m(t)‖ — the error-compensated gradient entering
    /// sparsification (for schemes without error accumulation, ‖g_m‖).
    pub pre_sparsify_norm: f64,
    /// ‖sp_k(g_m + Δ_m(t))‖ — what survived sparsification. Computed via
    /// the disjoint-support identity ‖g_sp‖² = ‖g_ec‖² − ‖Δ(t+1)‖² for
    /// analog schemes; for digital schemes, the norm of the quantized
    /// reconstruction.
    pub post_sparsify_norm: f64,
    /// ‖Δ_m(t+1)‖ — the residual banked for later rounds.
    pub accumulator_norm: f64,
    /// Fading gain h_m(t). `None` for links without a fading process.
    pub fading_gain: Option<f64>,
    /// ‖x_m(t)‖² actually radiated this round (0 for silent devices;
    /// `ctx.p_t` for digital transmitters, which spend the full budget).
    pub tx_energy: f64,
    /// Where this device went this round.
    pub outcome: DeviceOutcome,
    /// Digital links: this device's actual payload size in bits.
    pub payload_bits: Option<f64>,
    /// D2D links: how many devices (incl. itself) this receiver heard —
    /// its closed-neighborhood transmit-set size.
    pub d2d_tx_set: Option<usize>,
}

impl DeviceDiag {
    /// A fresh record for device `m` with every optional field absent and
    /// the default outcome `Transmitting` (schemes overwrite as needed).
    pub fn new(device: usize) -> DeviceDiag {
        DeviceDiag {
            device,
            pre_sparsify_norm: 0.0,
            post_sparsify_norm: 0.0,
            accumulator_norm: 0.0,
            fading_gain: None,
            tx_energy: 0.0,
            outcome: DeviceOutcome::Transmitting,
            payload_bits: None,
            d2d_tx_set: None,
        }
    }
}

/// Everything one link round can report about itself.
#[derive(Clone, Debug, Default)]
pub struct RoundDiagnostics {
    /// Iteration index t.
    pub t: usize,
    /// The producing scheme's [`super::LinkScheme::name`].
    pub scheme: &'static str,
    /// Per-device records, in device order, length M.
    pub devices: Vec<DeviceDiag>,
    /// The round's power budget P_t (Eq. 6 per-round allocation).
    pub power_budget: f64,
    /// Eq. 6 headroom gauge: P_t − max_m ‖x_m(t)‖². Positive means every
    /// device radiated under budget this round.
    pub power_headroom: f64,
    /// Effective receive SNR in dB: per-channel-use received signal power
    /// (Σ_m ‖h_m·x_m‖²/s) over the MAC noise variance. `None` when the
    /// link has no noise model (error-free) or nobody transmitted.
    pub effective_snr_db: Option<f64>,
    /// AMP iterations the decode ran (max over receivers for D2D).
    pub amp_iterations: usize,
    /// Final AMP state-evolution residual τ from the decode trace.
    pub amp_final_residual: Option<f64>,
    /// Digital links: the round's capacity budget R_t in bits.
    pub quant_budget_bits: Option<f64>,
    /// Decentralized links: RMS replica disagreement after mixing.
    pub consensus_distance: Option<f64>,
}

impl RoundDiagnostics {
    pub fn new(t: usize, scheme: &'static str, devices: usize) -> RoundDiagnostics {
        RoundDiagnostics {
            t,
            scheme,
            devices: (0..devices).map(DeviceDiag::new).collect(),
            ..RoundDiagnostics::default()
        }
    }

    /// Participation counts implied by the per-device outcomes.
    pub fn participation_counts(&self) -> (usize, usize, usize, usize) {
        let mut c = (0usize, 0usize, 0usize, 0usize);
        for d in &self.devices {
            match d.outcome {
                DeviceOutcome::Transmitting => c.0 += 1,
                DeviceOutcome::NotScheduled => c.1 += 1,
                DeviceOutcome::SilencedLowGain => c.2 += 1,
                DeviceOutcome::DroppedStraggler => c.3 += 1,
            }
        }
        c
    }
}

/// A shared, clonable buffer the trainer hands to the link; the link
/// pushes one [`RoundDiagnostics`] per round, the trainer drains it after
/// each round and forwards to its `diag_observer`. Plain `Arc<Mutex<_>>`
/// because production use is strictly single-producer single-consumer
/// within one round — the lock is never contended, it just keeps the type
/// `Send + Sync` without unsafe.
#[derive(Clone, Default)]
pub struct DiagSink {
    inner: Arc<Mutex<Vec<RoundDiagnostics>>>,
}

impl DiagSink {
    pub fn new() -> DiagSink {
        DiagSink::default()
    }

    /// Append one round's diagnostics.
    pub fn record(&self, d: RoundDiagnostics) {
        self.inner.lock().unwrap().push(d);
    }

    /// Take everything recorded since the last drain.
    pub fn drain(&self) -> Vec<RoundDiagnostics> {
        std::mem::take(&mut *self.inner.lock().unwrap())
    }
}

/// Effective receive SNR in dB from summed received signal energy over `s`
/// channel uses with per-use noise variance `noise_var`. Returns `None`
/// when nothing was received or the link is noiseless.
pub fn snr_db(received_energy: f64, s: usize, noise_var: f64) -> Option<f64> {
    if received_energy <= 0.0 || noise_var <= 0.0 || s == 0 {
        return None;
    }
    let per_use = received_energy / s as f64;
    Some(10.0 * (per_use / noise_var).log10())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_codes_roundtrip() {
        for o in [
            DeviceOutcome::Transmitting,
            DeviceOutcome::NotScheduled,
            DeviceOutcome::SilencedLowGain,
            DeviceOutcome::DroppedStraggler,
        ] {
            assert_eq!(DeviceOutcome::from_code(o.code()), Some(o));
        }
        assert_eq!(DeviceOutcome::from_code(99), None);
    }

    #[test]
    fn sink_drains_in_order_and_empties() {
        let sink = DiagSink::new();
        sink.record(RoundDiagnostics::new(0, "x", 2));
        sink.record(RoundDiagnostics::new(1, "x", 2));
        let got = sink.drain();
        assert_eq!(got.len(), 2);
        assert_eq!((got[0].t, got[1].t), (0, 1));
        assert!(sink.drain().is_empty());
        // Clones share the same buffer.
        let other = sink.clone();
        other.record(RoundDiagnostics::new(7, "x", 1));
        assert_eq!(sink.drain().len(), 1);
    }

    #[test]
    fn participation_counts_sum_to_m() {
        let mut d = RoundDiagnostics::new(3, "fading-A-DSGD", 4);
        d.devices[1].outcome = DeviceOutcome::NotScheduled;
        d.devices[2].outcome = DeviceOutcome::SilencedLowGain;
        d.devices[3].outcome = DeviceOutcome::DroppedStraggler;
        assert_eq!(d.participation_counts(), (1, 1, 1, 1));
    }

    #[test]
    fn snr_db_behaves() {
        // 100 units over 10 uses, unit noise → 10 per use → 10 dB.
        let v = snr_db(100.0, 10, 1.0).unwrap();
        assert!((v - 10.0).abs() < 1e-12, "{v}");
        assert_eq!(snr_db(0.0, 10, 1.0), None);
        assert_eq!(snr_db(5.0, 10, 0.0), None);
    }
}
