//! Gradient backends: where per-device gradients come from.
//!
//! The coordinator is backend-agnostic: [`RustBackend`] computes gradients
//! with the pure-rust model (reference path); [`crate::runtime::PjrtBackend`]
//! executes the AOT-lowered JAX graph (L2, which itself calls the L1 Pallas
//! kernels) through the PJRT CPU client. Both produce the `[M, d]` matrix of
//! per-device gradients for identical inputs — an integration test asserts
//! they agree numerically.

use crate::data::Dataset;
use crate::tensor::Matf;

/// Produces per-device gradient estimates g_m(θ_t) for all M devices.
///
/// Not `Send`: the PJRT backend wraps non-Send FFI handles; the trainer
/// drives backends from the leader thread only (workers parallelize
/// *inside* a backend call).
pub trait GradientBackend {
    /// `params`: flat θ (d); `shards[m]`: device m's sample indices into
    /// `train`. Returns an M×d matrix, row m = g_m(θ).
    fn per_device_gradients(
        &mut self,
        params: &[f32],
        train: &Dataset,
        shards: &[Vec<usize>],
    ) -> Matf;

    /// Replica variant for decentralized links: row m of `replicas` is
    /// device m's own model, and row m of the result is g_m(θ_m). The
    /// default evaluates each device's shard at its replica through
    /// [`GradientBackend::per_device_gradients`], which makes the path
    /// bit-identical to the shared-model call whenever all replicas agree
    /// (each row is produced by the same per-shard gradient computation).
    fn per_device_gradients_at(
        &mut self,
        replicas: &Matf,
        train: &Dataset,
        shards: &[Vec<usize>],
    ) -> Matf {
        assert_eq!(replicas.rows, shards.len(), "one replica row per shard");
        let mut out = Matf::zeros(shards.len(), replicas.cols);
        for m in 0..shards.len() {
            let row = self.per_device_gradients(
                replicas.row(m),
                train,
                std::slice::from_ref(&shards[m]),
            );
            out.row_mut(m).copy_from_slice(row.row(0));
        }
        out
    }

    fn name(&self) -> &'static str;
}

/// Pure-rust reference backend (thread-parallel across devices).
pub struct RustBackend {
    workers: usize,
}

impl RustBackend {
    pub fn new() -> RustBackend {
        RustBackend { workers: 0 }
    }

    pub fn with_workers(workers: usize) -> RustBackend {
        RustBackend { workers }
    }
}

impl Default for RustBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl GradientBackend for RustBackend {
    fn per_device_gradients(
        &mut self,
        params: &[f32],
        train: &Dataset,
        shards: &[Vec<usize>],
    ) -> Matf {
        let workers = if self.workers == 0 {
            crate::util::threadpool::default_workers(shards.len())
        } else {
            self.workers
        };
        crate::model::per_device_gradients(params, train, shards, workers)
    }

    /// Parallel override: fan the M independent (replica, shard) gradient
    /// evaluations across the worker pool. Row m runs the same
    /// `model::gradient` call as the default implementation (and as the
    /// shared-model path), so the result is bit-identical — only faster.
    fn per_device_gradients_at(
        &mut self,
        replicas: &Matf,
        train: &Dataset,
        shards: &[Vec<usize>],
    ) -> Matf {
        assert_eq!(replicas.rows, shards.len(), "one replica row per shard");
        let m = shards.len();
        let workers = if self.workers == 0 {
            crate::util::threadpool::default_workers(m)
        } else {
            self.workers
        };
        let rows = crate::util::threadpool::par_map(m, workers, |dev| {
            let mut g = vec![0f32; replicas.cols];
            crate::model::gradient(replicas.row(dev), train, &shards[dev], &mut g);
            g
        });
        let mut out = Matf::zeros(m, replicas.cols);
        for (r, row) in rows.into_iter().enumerate() {
            out.row_mut(r).copy_from_slice(&row);
        }
        out
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn rust_backend_shapes() {
        let ds = synthetic::generate(40, 1, 0);
        let shards = vec![(0..20).collect::<Vec<_>>(), (20..40).collect::<Vec<_>>()];
        let params = vec![0f32; crate::model::PARAM_DIM];
        let mut be = RustBackend::new();
        let g = be.per_device_gradients(&params, &ds, &shards);
        assert_eq!(g.rows, 2);
        assert_eq!(g.cols, crate::model::PARAM_DIM);
        // Zero params → symmetric softmax → gradient rows non-zero.
        assert!(crate::tensor::norm(g.row(0)) > 0.0);
    }
}
