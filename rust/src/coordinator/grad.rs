//! Gradient backends: where per-device gradients come from.
//!
//! The coordinator is backend-agnostic: [`RustBackend`] computes gradients
//! with the pure-rust model (reference path); [`crate::runtime::PjrtBackend`]
//! executes the AOT-lowered JAX graph (L2, which itself calls the L1 Pallas
//! kernels) through the PJRT CPU client. Both produce the `[M, d]` matrix of
//! per-device gradients for identical inputs — an integration test asserts
//! they agree numerically.

use crate::data::Dataset;
use crate::tensor::Matf;

/// Produces per-device gradient estimates g_m(θ_t) for all M devices.
///
/// Not `Send`: the PJRT backend wraps non-Send FFI handles; the trainer
/// drives backends from the leader thread only (workers parallelize
/// *inside* a backend call).
pub trait GradientBackend {
    /// `params`: flat θ (d); `shards[m]`: device m's sample indices into
    /// `train`. Returns an M×d matrix, row m = g_m(θ).
    fn per_device_gradients(
        &mut self,
        params: &[f32],
        train: &Dataset,
        shards: &[Vec<usize>],
    ) -> Matf;

    fn name(&self) -> &'static str;
}

/// Pure-rust reference backend (thread-parallel across devices).
pub struct RustBackend {
    workers: usize,
}

impl RustBackend {
    pub fn new() -> RustBackend {
        RustBackend { workers: 0 }
    }

    pub fn with_workers(workers: usize) -> RustBackend {
        RustBackend { workers }
    }
}

impl Default for RustBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl GradientBackend for RustBackend {
    fn per_device_gradients(
        &mut self,
        params: &[f32],
        train: &Dataset,
        shards: &[Vec<usize>],
    ) -> Matf {
        let workers = if self.workers == 0 {
            crate::util::threadpool::default_workers(shards.len())
        } else {
            self.workers
        };
        crate::model::per_device_gradients(params, train, shards, workers)
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn rust_backend_shapes() {
        let ds = synthetic::generate(40, 1, 0);
        let shards = vec![(0..20).collect::<Vec<_>>(), (20..40).collect::<Vec<_>>()];
        let params = vec![0f32; crate::model::PARAM_DIM];
        let mut be = RustBackend::new();
        let g = be.per_device_gradients(&params, &ds, &shards);
        assert_eq!(g.rows, 2);
        assert_eq!(g.cols, crate::model::PARAM_DIM);
        // Zero params → symmetric softmax → gradient rows non-zero.
        assert!(crate::tensor::norm(g.row(0)) > 0.0);
    }
}
