//! The training orchestrator: the leader loop that drives synchronous DSGD
//! rounds end-to-end — gradient fan-out, scheme-specific transmission over
//! the (simulated) Gaussian MAC, PS-side reconstruction, optimizer step,
//! metrics — for every scheme in the paper.

use std::time::Instant;

use crate::amp::AmpConfig;
use crate::analog::{AnalogPs, Projection};
use crate::channel::{GaussianMac, PowerAllocator};
use crate::compress::DigitalPayload;
use crate::config::{RunConfig, Scheme};
use crate::data::{load_corpus, partition, Corpus};
use crate::digital::{aggregate, capacity_bits};
use crate::model::PARAM_DIM;
use crate::optim::{Adam, Optimizer};
use crate::util::rng::Pcg64;

use super::device::DeviceState;
use super::grad::{GradientBackend, RustBackend};
use super::metrics::{RoundRecord, TrainLog};

/// End-to-end trainer for one `RunConfig`.
pub struct Trainer {
    pub cfg: RunConfig,
    corpus: Corpus,
    shards: Vec<Vec<usize>>,
    backend: Box<dyn GradientBackend>,
    /// Progress printing (on for CLI, off for tests/benches).
    pub verbose: bool,
}

impl Trainer {
    /// Build a trainer: load corpus, partition across devices.
    pub fn new(cfg: RunConfig) -> anyhow::Result<Trainer> {
        Self::with_backend(cfg, Box::new(RustBackend::new()))
    }

    pub fn with_backend(
        cfg: RunConfig,
        backend: Box<dyn GradientBackend>,
    ) -> anyhow::Result<Trainer> {
        cfg.validate(PARAM_DIM)
            .map_err(|e| anyhow::anyhow!(e.to_string()))?;
        let corpus = load_corpus(&cfg.dataset, cfg.seed)?;
        let mut rng = Pcg64::with_stream(cfg.seed, 0x9A47);
        let shards = if cfg.noniid {
            partition::non_iid(&corpus.train, cfg.devices, cfg.local_samples, &mut rng)
        } else {
            partition::iid(&corpus.train, cfg.devices, cfg.local_samples, &mut rng)
        };
        Ok(Trainer {
            cfg,
            corpus,
            shards,
            backend,
            verbose: false,
        })
    }

    pub fn corpus(&self) -> &Corpus {
        &self.corpus
    }

    pub fn shards(&self) -> &[Vec<usize>] {
        &self.shards
    }

    /// Run the full T-iteration job.
    pub fn run(&mut self) -> TrainLog {
        let cfg = self.cfg.clone();
        let t_start = Instant::now();
        let d = PARAM_DIM;
        let m = cfg.devices;

        // PS state: θ_0 = 0 (Alg. 1 line 1), ADAM as in §VI.
        let mut params = vec![0f32; d];
        let mut optimizer: Box<dyn Optimizer> = Box::new(Adam::new(d, cfg.lr as f32));
        let power = PowerAllocator::new(cfg.power, cfg.pbar, cfg.iterations);

        // Device state.
        let mut devices: Vec<DeviceState> = (0..m)
            .map(|i| {
                DeviceState::new(
                    cfg.scheme,
                    d,
                    cfg.sparsity,
                    cfg.qsgd_levels,
                    cfg.seed.wrapping_add(i as u64),
                )
            })
            .collect();

        // Channel + analog decoders.
        let mut mac = GaussianMac::new(cfg.channel_uses, m, cfg.noise_var, cfg.seed ^ 0xC4A);
        let amp_cfg = AmpConfig {
            max_iters: cfg.amp_iters,
            tol: cfg.amp_tol,
            threshold_mult: cfg.amp_threshold_mult as f32,
        };
        let (mut ps_std, mut ps_mr): (Option<AnalogPs>, Option<AnalogPs>) = (None, None);
        if cfg.scheme == Scheme::ADsgd {
            ps_std = Some(AnalogPs::new(
                Projection::generate(cfg.channel_uses - 1, d, cfg.seed ^ 0xA57D),
                amp_cfg,
            ));
            if cfg.mean_removal_rounds > 0 {
                ps_mr = Some(AnalogPs::new(
                    Projection::generate(cfg.channel_uses - 2, d, cfg.seed ^ 0xA57E),
                    amp_cfg,
                ));
            }
        }

        // Digital energy meter (digital frames don't traverse the MAC
        // simulator — capacity-achieving codes are assumed — but devices
        // still spend ‖x‖² = P_t per round; Eq. 6 must hold regardless).
        let mut digital_energy = vec![0f64; m];
        let mut digital_rounds = 0usize;

        let mut log = TrainLog {
            label: cfg.scheme.name().to_string(),
            records: Vec::with_capacity(cfg.iterations),
            measured_avg_power: vec![0.0; m],
            pbar: cfg.pbar,
            final_accuracy: 0.0,
            total_secs: 0.0,
        };

        for t in 0..cfg.iterations {
            let round_start = Instant::now();
            let p_t = power.p(t);

            // 1. Device gradient computation (parallel fan-out).
            let grads = self
                .backend
                .per_device_gradients(&params, &self.corpus.train, &self.shards);

            // 2. Transmission + PS reconstruction.
            let mut bits_per_device = 0.0;
            let mut amp_iterations = 0usize;
            let ghat: Vec<f32> = match cfg.scheme {
                Scheme::ErrorFree => {
                    let mut avg = vec![0f32; d];
                    for dev in 0..m {
                        crate::tensor::axpy(1.0 / m as f32, grads.row(dev), &mut avg);
                    }
                    avg
                }
                Scheme::DDsgd | Scheme::SignSgd | Scheme::Qsgd => {
                    let budget = capacity_bits(cfg.channel_uses, m, p_t, cfg.noise_var);
                    bits_per_device = budget;
                    let payloads: Vec<DigitalPayload> = devices
                        .iter_mut()
                        .enumerate()
                        .map(|(dev, state)| {
                            state.as_digital_mut().transmit(grads.row(dev), budget)
                        })
                        .collect();
                    bits_per_device = payloads
                        .iter()
                        .map(|p| p.bits)
                        .fold(0.0, f64::max)
                        .min(bits_per_device);
                    for e in digital_energy.iter_mut() {
                        *e += p_t;
                    }
                    digital_rounds += 1;
                    aggregate(&payloads, d)
                }
                Scheme::ADsgd => {
                    let mean_removal = t < cfg.mean_removal_rounds;
                    let (frames, decoder): (Vec<Vec<f32>>, &AnalogPs) = if mean_removal {
                        let ps = ps_mr.as_ref().expect("mean-removal decoder");
                        let proj = ps.projection();
                        let frames = devices
                            .iter_mut()
                            .enumerate()
                            .map(|(dev, state)| {
                                state
                                    .as_analog_mut()
                                    .transmit_mean_removed(
                                        grads.row(dev),
                                        proj,
                                        p_t,
                                        cfg.channel_uses,
                                    )
                                    .x
                            })
                            .collect();
                        (frames, ps)
                    } else {
                        let ps = ps_std.as_ref().expect("analog decoder");
                        let proj = ps.projection();
                        let frames = devices
                            .iter_mut()
                            .enumerate()
                            .map(|(dev, state)| {
                                state
                                    .as_analog_mut()
                                    .transmit(grads.row(dev), proj, p_t)
                                    .x
                            })
                            .collect();
                        (frames, ps)
                    };
                    let y = mac.transmit(&frames);
                    let (ghat, trace) = if mean_removal {
                        decoder.decode_mean_removed(&y)
                    } else {
                        decoder.decode(&y)
                    };
                    amp_iterations = trace.iterations;
                    // Free the mean-removal projection once past its phase.
                    if !mean_removal && ps_mr.is_some() {
                        ps_mr = None;
                    }
                    ghat
                }
            };

            // 3. PS update: θ_{t+1} = θ_t − η·ĝ (through ADAM).
            optimizer.step(&mut params, &ghat);

            // 4. Metrics.
            let evaluate = t % cfg.eval_every == 0 || t + 1 == cfg.iterations;
            let (acc, loss) = if evaluate {
                let acc = crate::model::accuracy(&params, &self.corpus.test);
                let loss =
                    crate::model::loss(&params, &self.corpus.train, &self.shards[0]);
                (acc, loss)
            } else {
                (f64::NAN, f64::NAN)
            };
            let acc_norm = devices
                .iter()
                .map(|s| s.accumulator_norm())
                .sum::<f64>()
                / m as f64;
            let record = RoundRecord {
                iter: t,
                test_accuracy: acc,
                train_loss: loss,
                grad_norm: crate::tensor::norm(&ghat),
                bits_per_device,
                p_t,
                amp_iterations,
                accumulator_norm: acc_norm,
                round_secs: round_start.elapsed().as_secs_f64(),
            };
            if self.verbose && evaluate {
                log.print_progress(&record);
            }
            if !acc.is_nan() {
                log.final_accuracy = acc;
            }
            log.records.push(record);
        }

        // Power audit: analog from the MAC meter, digital from P_t spend.
        log.measured_avg_power = match cfg.scheme {
            Scheme::ADsgd => {
                let rep = mac.power_report();
                (0..m).map(|dev| rep.avg_power(dev)).collect()
            }
            Scheme::ErrorFree => vec![0.0; m],
            _ => digital_energy
                .iter()
                .map(|&e| e / digital_rounds.max(1) as f64)
                .collect(),
        };
        log.total_secs = t_start.elapsed().as_secs_f64();
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn smoke_cfg(scheme: Scheme) -> RunConfig {
        RunConfig {
            scheme,
            iterations: 6,
            eval_every: 2,
            ..presets::smoke()
        }
    }

    #[test]
    fn error_free_learns() {
        let mut tr = Trainer::new(smoke_cfg(Scheme::ErrorFree)).unwrap();
        let log = tr.run();
        assert_eq!(log.records.len(), 6);
        assert!(log.final_accuracy > 0.3, "acc={}", log.final_accuracy);
    }

    #[test]
    fn adsgd_runs_and_respects_power() {
        let mut tr = Trainer::new(smoke_cfg(Scheme::ADsgd)).unwrap();
        let log = tr.run();
        assert!(log.power_constraint_ok(1e-6), "{:?}", log.measured_avg_power);
        assert!(log.records.iter().skip(1).any(|r| r.amp_iterations > 0));
        // Mean-removal rounds happen first (3 in smoke preset).
        assert!(log.records[0].amp_iterations > 0);
    }

    #[test]
    fn ddsgd_respects_power_and_bits() {
        let mut tr = Trainer::new(smoke_cfg(Scheme::DDsgd)).unwrap();
        let log = tr.run();
        assert!(log.power_constraint_ok(1e-6));
        for r in &log.records {
            assert!(r.bits_per_device > 0.0);
        }
    }

    #[test]
    fn all_schemes_execute() {
        for scheme in [
            Scheme::ErrorFree,
            Scheme::ADsgd,
            Scheme::DDsgd,
            Scheme::SignSgd,
            Scheme::Qsgd,
        ] {
            let mut tr = Trainer::new(smoke_cfg(scheme)).unwrap();
            let log = tr.run();
            assert_eq!(log.records.len(), 6, "{scheme:?}");
            assert!(log.final_accuracy > 0.05, "{scheme:?}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut tr = Trainer::new(smoke_cfg(Scheme::ADsgd)).unwrap();
            tr.run()
                .records
                .iter()
                .map(|r| r.grad_norm)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
