//! The training orchestrator: the leader loop that drives synchronous DSGD
//! rounds end-to-end. The loop itself is scheme-agnostic — per-round it asks
//! the gradient backend for the `M × d` gradient matrix, hands it to the
//! run's [`LinkScheme`] (which encodes, traverses the channel, and
//! reconstructs ĝ at the PS), and steps the optimizer. Everything
//! scheme-specific lives behind [`crate::coordinator::link`].

use std::time::Instant;

use crate::campaign::snapshot::{SnapshotReader, SnapshotWriter, TrainerSnapshot};
use crate::campaign::store::config_hash;
use crate::channel::PowerAllocator;
use crate::config::RunConfig;
use crate::data::{load_corpus, partition, Corpus};
use crate::model::PARAM_DIM;
use crate::optim::{Adam, Optimizer};
use crate::util::rng::Pcg64;

use super::grad::{GradientBackend, RustBackend};
use super::link::{self, DiagSink, LinkScheme, RoundCtx, RoundDiagnostics};
use super::metrics::{RoundRecord, TrainLog};

/// End-to-end trainer for one `RunConfig`.
pub struct Trainer {
    pub cfg: RunConfig,
    corpus: Corpus,
    shards: Vec<Vec<usize>>,
    backend: Box<dyn GradientBackend>,
    /// Progress printing (on for CLI, off for tests/benches).
    pub verbose: bool,
    /// Observe-only per-round telemetry hook, called with each round's
    /// [`RoundRecord`] after it is finalized (the campaign scheduler
    /// wires this to the fleet event log). It sees the record, never
    /// mutates trainer state — trajectories are bit-identical with or
    /// without an observer installed.
    pub round_observer: Option<Box<dyn FnMut(&RoundRecord) + Send>>,
    /// Observe-only link diagnostics hook. When set, the trainer installs
    /// a [`DiagSink`] on the link (via [`LinkScheme::probe`]) and forwards
    /// each round's [`RoundDiagnostics`] here, *before* `round_observer`
    /// sees the matching [`RoundRecord`]. Probes are read-only by
    /// construction — see [`super::link::diag`] — so trajectories stay
    /// bit-identical whether or not this hook is installed.
    pub diag_observer: Option<Box<dyn FnMut(&RoundDiagnostics) + Send>>,
}

impl Trainer {
    /// Build a trainer: load corpus, partition across devices.
    pub fn new(cfg: RunConfig) -> anyhow::Result<Trainer> {
        Self::with_backend(cfg, Box::new(RustBackend::new()))
    }

    pub fn with_backend(
        cfg: RunConfig,
        backend: Box<dyn GradientBackend>,
    ) -> anyhow::Result<Trainer> {
        cfg.validate(PARAM_DIM)
            .map_err(|e| anyhow::anyhow!(e.to_string()))?;
        let corpus = load_corpus(&cfg.dataset, cfg.seed)?;
        let mut rng = Pcg64::with_stream(cfg.seed, 0x9A47);
        let shards = if cfg.noniid {
            partition::non_iid(&corpus.train, cfg.devices, cfg.local_samples, &mut rng)
        } else {
            partition::iid(&corpus.train, cfg.devices, cfg.local_samples, &mut rng)
        };
        Ok(Trainer {
            cfg,
            corpus,
            shards,
            backend,
            verbose: false,
            round_observer: None,
            diag_observer: None,
        })
    }

    pub fn corpus(&self) -> &Corpus {
        &self.corpus
    }

    pub fn shards(&self) -> &[Vec<usize>] {
        &self.shards
    }

    /// Run the full T-iteration job.
    pub fn run(&mut self) -> TrainLog {
        self.run_with_snapshots(None, 0, &mut |_| {})
    }

    /// Resume a run from a [`TrainerSnapshot`] (taken by an earlier
    /// [`Trainer::run_with_snapshots`]); the remaining rounds replay
    /// bit-identically to the uninterrupted trajectory.
    pub fn resume(&mut self, snap: &TrainerSnapshot) -> TrainLog {
        self.run_with_snapshots(Some(snap), 0, &mut |_| {})
    }

    /// The general driver behind [`Trainer::run`] / [`Trainer::resume`]:
    /// optionally restore a snapshot first, then emit a new snapshot to
    /// `sink` after every `snapshot_every`-th round and after the final one
    /// (`snapshot_every = 0` disables emission). Restoring and re-emitting
    /// are exact inverses, so snapshots compose across any number of
    /// interruptions.
    pub fn run_with_snapshots(
        &mut self,
        resume: Option<&TrainerSnapshot>,
        snapshot_every: usize,
        sink: &mut dyn FnMut(&TrainerSnapshot),
    ) -> TrainLog {
        let t_start = Instant::now();
        let d = PARAM_DIM;

        // PS state: θ_0 = 0 (Alg. 1 line 1), ADAM as in §VI.
        let mut params = vec![0f32; d];
        let mut optimizer: Box<dyn Optimizer> = Box::new(Adam::new(d, self.cfg.lr as f32));
        let power = PowerAllocator::new(self.cfg.power, self.cfg.pbar, self.cfg.iterations);

        // The transmission pipeline: devices, channel, PS decoder, audit.
        let mut link = link::for_config(&self.cfg, d);

        // Link diagnostics: only pay for probes when someone is listening.
        let diag_sink = self.diag_observer.as_ref().map(|_| DiagSink::new());
        if let Some(sink) = &diag_sink {
            link.probe(Some(sink.clone()));
        }

        let mut log = TrainLog {
            label: self.cfg.scheme.name().to_string(),
            records: Vec::with_capacity(self.cfg.iterations),
            measured_avg_power: vec![0.0; self.cfg.devices],
            pbar: self.cfg.pbar,
            final_accuracy: 0.0,
            total_secs: 0.0,
        };

        let mut start_round = 0;
        if let Some(snap) = resume {
            assert_eq!(
                snap.config_hash,
                config_hash(&self.cfg),
                "snapshot belongs to a different RunConfig"
            );
            assert_eq!(snap.params.len(), d, "snapshot model dimension mismatch");
            assert!(
                snap.next_round <= self.cfg.iterations,
                "snapshot round {} beyond the configured horizon {}",
                snap.next_round,
                self.cfg.iterations
            );
            params.copy_from_slice(&snap.params);
            optimizer.import_state(&snap.optim_m, &snap.optim_v, snap.optim_t);
            let mut r = SnapshotReader::new(&snap.link);
            link.restore(&mut r).expect("link state restore");
            log.records = snap.records.clone();
            log.final_accuracy = snap.final_accuracy;
            start_round = snap.next_round;
        }

        for t in start_round..self.cfg.iterations {
            let round_start = Instant::now();
            let p_t = power.p(t);

            // 1. Device gradient computation (parallel fan-out). A
            // decentralized link exposes per-device model replicas; each
            // device's gradient is then taken at its own θ_i. PS-centric
            // links return None and keep the shared-model path bit-for-bit.
            let grads = {
                let _sp = crate::util::prof::span("gradient");
                match link.replicas() {
                    Some(replicas) => self.backend.per_device_gradients_at(
                        replicas,
                        &self.corpus.train,
                        &self.shards,
                    ),
                    None => self
                        .backend
                        .per_device_gradients(&params, &self.corpus.train, &self.shards),
                }
            };

            // 2. Transmission + reconstruction (for a decentralized link
            // this includes the consensus mixing and per-replica local
            // steps).
            let out = link.round(&RoundCtx { t, p_t, deadline: self.cfg.deadline() }, &grads);

            // 3. PS update: θ_{t+1} = θ_t − η·ĝ (through ADAM) — or, for
            // replica links, adopt the consensus average as the evaluation
            // model (the link already stepped its per-device optimizers).
            match link.replica_average() {
                Some(avg) => params = avg,
                None => optimizer.step(&mut params, &out.ghat),
            }

            // 4. Metrics.
            let evaluate = t % self.cfg.eval_every == 0 || t + 1 == self.cfg.iterations;
            let (acc, loss) = if evaluate {
                let _sp = crate::util::prof::span("eval");
                let acc = crate::model::accuracy(&params, &self.corpus.test);
                let loss =
                    crate::model::loss(&params, &self.corpus.train, &self.shards[0]);
                (acc, loss)
            } else {
                (f64::NAN, f64::NAN)
            };
            let record = RoundRecord {
                iter: t,
                test_accuracy: acc,
                train_loss: loss,
                grad_norm: crate::tensor::norm(&out.ghat),
                bits_per_device: out.telemetry.bits_per_device,
                p_t,
                amp_iterations: out.telemetry.amp_iterations,
                accumulator_norm: link.accumulator_norm(),
                round_secs: round_start.elapsed().as_secs_f64(),
                participation: out.telemetry.participation,
                consensus_distance: out.telemetry.consensus_distance,
            };
            if self.verbose && evaluate {
                log.print_progress(&record);
            }
            if !acc.is_nan() {
                log.final_accuracy = acc;
            }
            // Diagnostics drain first so a consumer correlating the two
            // streams has the round's device detail before its summary.
            if let (Some(sink), Some(observer)) = (&diag_sink, self.diag_observer.as_mut()) {
                for diag in sink.drain() {
                    observer(&diag);
                }
            }
            if let Some(observer) = self.round_observer.as_mut() {
                observer(&record);
            }
            log.records.push(record);

            if snapshot_every > 0 && ((t + 1) % snapshot_every == 0 || t + 1 == self.cfg.iterations)
            {
                sink(&self.take_snapshot(t + 1, &params, optimizer.as_ref(), link.as_ref(), &log));
            }
        }

        // Eq. 6 audit straight from the link's meters.
        log.measured_avg_power = link.measured_avg_power();
        log.total_secs = t_start.elapsed().as_secs_f64();
        log
    }

    /// Capture the complete mutable state after `next_round` rounds.
    fn take_snapshot(
        &self,
        next_round: usize,
        params: &[f32],
        optimizer: &dyn Optimizer,
        link: &dyn LinkScheme,
        log: &TrainLog,
    ) -> TrainerSnapshot {
        let (optim_m, optim_v, optim_t) = optimizer.export_state();
        let mut w = SnapshotWriter::new();
        link.snapshot(&mut w);
        TrainerSnapshot {
            config_hash: config_hash(&self.cfg),
            next_round,
            params: params.to_vec(),
            optim_m,
            optim_v,
            optim_t,
            link: w.into_bytes(),
            records: log.records.clone(),
            final_accuracy: log.final_accuracy,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Scheme};

    fn smoke_cfg(scheme: Scheme) -> RunConfig {
        RunConfig {
            scheme,
            iterations: 6,
            eval_every: 2,
            ..presets::smoke()
        }
    }

    #[test]
    fn error_free_learns() {
        let mut tr = Trainer::new(smoke_cfg(Scheme::ErrorFree)).unwrap();
        let log = tr.run();
        assert_eq!(log.records.len(), 6);
        assert!(log.final_accuracy > 0.3, "acc={}", log.final_accuracy);
    }

    #[test]
    fn adsgd_runs_and_respects_power() {
        let mut tr = Trainer::new(smoke_cfg(Scheme::ADsgd)).unwrap();
        let log = tr.run();
        assert!(log.power_constraint_ok(1e-6), "{:?}", log.measured_avg_power);
        assert!(log.records.iter().skip(1).any(|r| r.amp_iterations > 0));
        // Mean-removal rounds happen first (3 in smoke preset).
        assert!(log.records[0].amp_iterations > 0);
    }

    #[test]
    fn ddsgd_respects_power_and_bits() {
        let mut tr = Trainer::new(smoke_cfg(Scheme::DDsgd)).unwrap();
        let log = tr.run();
        assert!(log.power_constraint_ok(1e-6));
        for r in &log.records {
            assert!(r.bits_per_device > 0.0);
        }
    }

    #[test]
    fn fading_schemes_execute_and_report_participation() {
        for scheme in [Scheme::FadingADsgd, Scheme::BlindADsgd] {
            let mut cfg = smoke_cfg(scheme);
            cfg.latency_mean_secs = 0.005;
            cfg.deadline_secs = 0.02;
            let mut tr = Trainer::new(cfg).unwrap();
            let log = tr.run();
            assert_eq!(log.records.len(), 6, "{scheme:?}");
            assert!(log.power_constraint_ok(1e-6), "{scheme:?}: {:?}", log.measured_avg_power);
            for r in &log.records {
                let p = r.participation.expect("fading links report participation");
                assert_eq!(p.total(), 10, "{scheme:?} t={}", r.iter);
            }
        }
        // The static schemes must keep reporting None (absent ≠ 0).
        let mut static_tr = Trainer::new(smoke_cfg(Scheme::ErrorFree)).unwrap();
        assert!(static_tr
            .run()
            .records
            .iter()
            .all(|r| r.participation.is_none()));
    }

    #[test]
    fn all_schemes_execute() {
        for scheme in [
            Scheme::ErrorFree,
            Scheme::ADsgd,
            Scheme::DDsgd,
            Scheme::SignSgd,
            Scheme::Qsgd,
        ] {
            let mut tr = Trainer::new(smoke_cfg(scheme)).unwrap();
            let log = tr.run();
            assert_eq!(log.records.len(), 6, "{scheme:?}");
            assert!(log.final_accuracy > 0.05, "{scheme:?}");
        }
    }

    #[test]
    fn diag_observer_sees_every_round_and_never_perturbs() {
        use std::sync::{Arc, Mutex};
        let run = |probe: bool| {
            let mut tr = Trainer::new(smoke_cfg(Scheme::ADsgd)).unwrap();
            let collected: Arc<Mutex<Vec<RoundDiagnostics>>> = Arc::default();
            if probe {
                let c = Arc::clone(&collected);
                tr.diag_observer = Some(Box::new(move |d: &RoundDiagnostics| {
                    c.lock().unwrap().push(d.clone());
                }));
            }
            let norms: Vec<f64> = tr.run().records.iter().map(|r| r.grad_norm).collect();
            let diags = std::mem::take(&mut *collected.lock().unwrap());
            (norms, diags)
        };
        let (plain, none) = run(false);
        let (probed, diags) = run(true);
        assert_eq!(plain, probed, "probes must not perturb the trajectory");
        assert!(none.is_empty(), "no observer, no diagnostics");
        assert_eq!(diags.len(), 6, "one diagnostics record per round");
        for (t, d) in diags.iter().enumerate() {
            assert_eq!(d.t, t);
            assert_eq!(d.scheme, "A-DSGD");
            assert_eq!(d.devices.len(), 10, "smoke preset has 10 devices");
        }
    }

    /// Same seed → identical grad-norm series, for every link scheme (the
    /// per-scheme table the golden equivalence test in
    /// `rust/tests/golden_schemes.rs` builds on).
    #[test]
    fn deterministic_given_seed() {
        for scheme in [
            Scheme::ErrorFree,
            Scheme::ADsgd,
            Scheme::DDsgd,
            Scheme::SignSgd,
            Scheme::Qsgd,
        ] {
            let run = || {
                let mut tr = Trainer::new(smoke_cfg(scheme)).unwrap();
                tr.run()
                    .records
                    .iter()
                    .map(|r| r.grad_norm)
                    .collect::<Vec<_>>()
            };
            assert_eq!(run(), run(), "{scheme:?}");
        }
    }
}
