//! Cache-aware campaign execution in front of [`crate::experiments::runner`].
//!
//! For every run in an [`ExperimentSpec`] the scheduler consults the
//! content-addressed [`RunStore`] and executes only the delta:
//!
//! * **complete** — the cached [`TrainLog`] is loaded; nothing executes.
//! * **partial** — the latest [`TrainerSnapshot`] is restored and only the
//!   remaining rounds run (bit-identical to never having stopped).
//! * **absent** — the run executes from scratch, snapshotting every
//!   `snapshot_every` rounds so a crash costs at most one interval.
//!
//! Output files go through [`runner::write_outputs`], so a fully-cached
//! invocation regenerates `summary.csv` and the per-run CSVs byte-identical
//! to the original execution (asserted in `rust/tests/campaign_cache.rs`).

use crate::config::{CampaignConfig, RunConfig};
use crate::coordinator::link::RoundDiagnostics;
use crate::coordinator::{link, LinkScheme, RoundRecord, TrainLog, Trainer};
use crate::experiments::runner::{self, ExperimentSpec};
use crate::fleet::events::{EventKind, EventLog};
use crate::fleet::trace::{self, TraceLog};
use crate::model::PARAM_DIM;
use crate::util::threadpool::{default_workers, par_map};

use super::snapshot::{SnapshotReader, TrainerSnapshot};
use super::store::{cache_key, RunStore};

/// Attach the telemetry event log to a freshly opened store when the
/// campaign enables it (the scheduler's writer id is pid-scoped so two
/// campaigns sharing a store never share a segment file).
fn attach_telemetry(store: &RunStore, campaign: &CampaignConfig) {
    if !campaign.telemetry.enabled {
        return;
    }
    let writer = format!("sched-{}", std::process::id());
    if let Ok(log) = EventLog::open(store.root(), &writer) {
        store.attach_events(log);
    }
    if campaign.telemetry.trace {
        if let Ok(log) = TraceLog::open(store.root(), &writer) {
            store.attach_trace(log);
        }
    }
}

/// What the scheduler did with a spec's runs (the cache test's execution
/// counter).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CampaignReport {
    /// Runs executed from round 0.
    pub executed: usize,
    /// Runs resumed from a snapshot (counted separately from `executed`).
    pub resumed: usize,
    /// Runs served entirely from the cache.
    pub cached: usize,
}

enum Plan {
    Cached(TrainLog),
    Resume(TrainerSnapshot),
    Fresh,
}

/// What happened to a single cached run (`repro train` reports this).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunDisposition {
    /// Served entirely from the cache; nothing executed.
    Cached,
    /// Resumed from a stored snapshot at this round.
    Resumed(usize),
    /// Executed from round 0.
    Executed,
}

/// Decide how to serve one run from the store: cached result, snapshot
/// resume (through the retained history if the latest blob is corrupt), or
/// fresh execution.
fn plan_run(store: &RunStore, label: &str, cfg: &RunConfig, campaign: &CampaignConfig) -> Plan {
    if let Some(log) = store.load_result(cfg) {
        return Plan::Cached(log);
    }
    if campaign.resume {
        if let Some(snap) = store.load_best_snapshot(cfg) {
            if snapshot_restorable(cfg, &snap) {
                return Plan::Resume(snap);
            }
            eprintln!(
                "warning: stored snapshot for `{label}` does not restore cleanly; \
                 re-running from scratch"
            );
        }
    }
    Plan::Fresh
}

/// Execute a spec through the run store. Returns the logs (in spec order,
/// labels applied) plus the execution report.
pub fn run_experiment_cached(
    spec: &ExperimentSpec,
    out_dir: &str,
    verbose: bool,
    campaign: &CampaignConfig,
) -> (Vec<TrainLog>, CampaignReport) {
    let store_dir = campaign.store_dir_or(out_dir);
    let store = RunStore::open(&store_dir).expect("open campaign run store");
    attach_telemetry(&store, campaign);
    println!("\n### {} — {} [store: {store_dir}]", spec.id, spec.title);

    let plan: Vec<Plan> = spec
        .runs
        .iter()
        .map(|(label, cfg)| plan_run(&store, label, cfg, campaign))
        .collect();

    let mut report = CampaignReport::default();
    for (step, (label, cfg)) in plan.iter().zip(&spec.runs) {
        match step {
            Plan::Cached(_) => {
                report.cached += 1;
                if let Some(log) = store.event_log() {
                    log.emit(EventKind::Cached, &cache_key(cfg), None, &[]);
                }
                println!("--- run `{label}`: cached ({})", cfg.summary());
            }
            Plan::Resume(snap) => {
                report.resumed += 1;
                println!(
                    "--- run `{label}` [{} link]: resuming round {}/{} — {}",
                    cfg.scheme.kind().name(),
                    snap.next_round,
                    cfg.iterations,
                    cfg.summary()
                );
            }
            Plan::Fresh => {
                report.executed += 1;
                runner::print_run_header(label, cfg);
            }
        }
    }

    // Execute the delta — parallel across runs when quiet, like the
    // plain runner (cached entries are free either way).
    let workers = if verbose {
        1
    } else {
        default_workers(spec.runs.len())
    };
    let logs: Vec<TrainLog> = par_map(spec.runs.len(), workers, |i| {
        let (label, cfg) = &spec.runs[i];
        match &plan[i] {
            Plan::Cached(log) => {
                let mut log = log.clone();
                log.label = label.clone();
                log
            }
            Plan::Resume(snap) => execute_run(&store, label, cfg, Some(snap), campaign, verbose),
            Plan::Fresh => execute_run(&store, label, cfg, None, campaign, verbose),
        }
    });

    runner::write_outputs(spec, &logs, out_dir);
    (logs, report)
}

/// Serve one standalone run through the store (`repro train`'s
/// checkpointing path): cached results load, partial runs resume from
/// their latest restorable snapshot, and fresh runs snapshot as they go —
/// the exact machinery the figure campaigns use, at fleet size one.
pub fn run_single_cached(
    label: &str,
    cfg: &RunConfig,
    out_dir: &str,
    verbose: bool,
    campaign: &CampaignConfig,
) -> (TrainLog, RunDisposition) {
    let store_dir = campaign.store_dir_or(out_dir);
    let store = RunStore::open(&store_dir).expect("open campaign run store");
    attach_telemetry(&store, campaign);
    match plan_run(&store, label, cfg, campaign) {
        Plan::Cached(mut log) => {
            if let Some(ev) = store.event_log() {
                ev.emit(EventKind::Cached, &cache_key(cfg), None, &[]);
            }
            log.label = label.to_string();
            (log, RunDisposition::Cached)
        }
        Plan::Resume(snap) => {
            let round = snap.next_round;
            let log = execute_run(&store, label, cfg, Some(&snap), campaign, verbose);
            (log, RunDisposition::Resumed(round))
        }
        Plan::Fresh => {
            let log = execute_run(&store, label, cfg, None, campaign, verbose);
            (log, RunDisposition::Executed)
        }
    }
}

/// Pre-flight a stored snapshot: the trainer's restore path panics on a
/// blob it cannot apply (honest for a direct `Trainer::resume`, fatal for
/// a campaign), so the scheduler proves the link state restores into a
/// freshly built link first and falls back to a fresh run otherwise. The
/// extra link construction is paid only on actual resumes — cheap next to
/// losing the whole campaign to one torn blob.
pub(crate) fn snapshot_restorable(cfg: &RunConfig, snap: &TrainerSnapshot) -> bool {
    if snap.params.len() != PARAM_DIM
        || snap.optim_m.len() != PARAM_DIM
        || snap.optim_v.len() != PARAM_DIM
        || snap.next_round > cfg.iterations
        || snap.records.len() != snap.next_round
    {
        return false;
    }
    let mut probe = link::for_config(cfg, PARAM_DIM);
    probe.restore(&mut SnapshotReader::new(&snap.link)).is_ok()
}

/// Execute (or resume) one run, snapshotting into the store with the
/// campaign's retention policy along the way. Shared with the fleet
/// worker loop (`crate::fleet::worker`), which adds lease heartbeating
/// around it.
///
/// This is also the central telemetry emission point: when the store
/// carries an event log, the run's `executed`/`resumed` start, every
/// persisted `snapshot`, per-round `round` telemetry (at the
/// `[telemetry]` cadence), and the final `completed` record are all
/// emitted here — so the campaign, `repro train`, and fleet-worker
/// paths produce one uniform event stream. Telemetry is observe-only:
/// trajectories and stored blobs are byte-identical with it disabled.
pub(crate) fn execute_run(
    store: &RunStore,
    label: &str,
    cfg: &RunConfig,
    resume: Option<&TrainerSnapshot>,
    campaign: &CampaignConfig,
    verbose: bool,
) -> TrainLog {
    cfg.validate(PARAM_DIM).expect("invalid experiment config");
    let mut trainer = Trainer::new(cfg.clone()).expect("trainer construction");
    trainer.verbose = verbose;
    let events = store.event_log();
    let key = cache_key(cfg);
    // Fleet tracing (observe-only, pure wall-clock): an `execute` span
    // covering the whole run, a `resume` marker when restoring, and —
    // when this run wins the per-process claim on the phase profiler —
    // per-round trainer phase spans drained into the trace. Declared
    // after `_run_token` so the drain drops (and flushes) first.
    let traces = if campaign.telemetry.enabled && campaign.telemetry.trace {
        store.trace_log()
    } else {
        None
    };
    let _run_token = traces.as_ref().map(|_| trace::RunToken::new());
    let _exec_span = traces.as_ref().map(|t| t.scope("execute", &key, None));
    if let Some(t) = &traces {
        if let Some(snap) = resume {
            t.mark("resume", &key, "", Some(snap.next_round as u64));
        }
    }
    let drain = traces
        .as_ref()
        .and_then(|t| trace::ProfDrain::claim(t.clone(), &key))
        .map(std::sync::Arc::new);
    let every = campaign.telemetry.every.max(1);
    let last = cfg.iterations.saturating_sub(1);
    // Round-level link aggregates, carried from the diag observer
    // (which the trainer calls first) into the same round's `round`
    // event payload. Arc<Mutex<..>> only to satisfy the two `Send`
    // closures — both run on the trainer thread, in order.
    let link_agg: std::sync::Arc<std::sync::Mutex<Option<(u64, Vec<(&'static str, f64)>)>>> =
        std::sync::Arc::default();
    if let Some(ev) = &events {
        match resume {
            Some(snap) => ev.emit(EventKind::Resumed, &key, Some(snap.next_round as u64), &[]),
            None => ev.emit(EventKind::Executed, &key, None, &[]),
        }
        if campaign.telemetry.diagnostics {
            let dev_ev = ev.clone();
            let dev_key = key.clone();
            let agg = std::sync::Arc::clone(&link_agg);
            trainer.diag_observer = Some(Box::new(move |d: &RoundDiagnostics| {
                let (tx, _, _, _) = d.participation_counts();
                let mut fields: Vec<(&'static str, f64)> =
                    vec![("participating", tx as f64), ("power_headroom", d.power_headroom)];
                if let Some(v) = d.effective_snr_db {
                    fields.push(("snr_db", v));
                }
                if d.amp_iterations > 0 {
                    fields.push(("amp_iterations", d.amp_iterations as f64));
                }
                if let Some(v) = d.amp_final_residual {
                    fields.push(("amp_residual", v));
                }
                *agg.lock().unwrap() = Some((d.t as u64, fields));
                if d.t % every == 0 || d.t == last {
                    for dev in &d.devices {
                        let mut data: Vec<(&'static str, f64)> = vec![
                            ("device", dev.device as f64),
                            ("outcome", dev.outcome.code() as f64),
                            ("pre_sparsify_norm", dev.pre_sparsify_norm),
                            ("post_sparsify_norm", dev.post_sparsify_norm),
                            ("accumulator_norm", dev.accumulator_norm),
                            ("tx_energy", dev.tx_energy),
                        ];
                        if let Some(h) = dev.fading_gain {
                            data.push(("fading_gain", h));
                        }
                        if let Some(b) = dev.payload_bits {
                            data.push(("payload_bits", b));
                        }
                        if let Some(n) = dev.d2d_tx_set {
                            data.push(("d2d_tx_set", n as f64));
                        }
                        dev_ev.emit(EventKind::Device, &dev_key, Some(d.t as u64), &data);
                    }
                }
            }));
        }
    }
    if events.is_some() || drain.is_some() {
        let ev = events.clone();
        let obs_key = key.clone();
        let round_drain = drain.clone();
        trainer.round_observer = Some(Box::new(move |r: &RoundRecord| {
            // Phase spans accumulated during this round are drained
            // every round (not cadence-thinned — a span stream with
            // holes can't support critical-path analysis).
            if let Some(d) = &round_drain {
                d.drain(Some(r.iter as u64));
            }
            // Cadence-thinned, but the final round always lands so the
            // last gauges (grad norm, accuracy) are current. Wall-clock
            // round_secs is deliberately NOT emitted: `ms` is the only
            // nondeterministic event field (see the replay contract).
            if r.iter % every == 0 || r.iter == last {
                let mut data: Vec<(&str, f64)> = vec![
                    ("grad_norm", r.grad_norm),
                    ("test_accuracy", r.test_accuracy),
                    ("train_loss", r.train_loss),
                    ("p_t", r.p_t),
                ];
                if let Some(c) = r.consensus_distance {
                    data.push(("consensus_distance", c));
                }
                if let Some((t, fields)) = link_agg.lock().unwrap().take() {
                    if t == r.iter as u64 {
                        data.extend(fields);
                    }
                }
                if let Some(ev) = &ev {
                    ev.emit(EventKind::Round, &obs_key, Some(r.iter as u64), &data);
                }
            }
        }));
    }
    let mut sink = |snap: &TrainerSnapshot| {
        let _sp = traces
            .as_ref()
            .map(|t| t.scope("snapshot_save", &key, Some(snap.next_round as u64)));
        // A failed snapshot write must not kill the run it protects.
        match store.save_snapshot_retained(cfg, label, snap, campaign.keep_last_n) {
            Ok(()) => {
                if let Some(ev) = &events {
                    ev.emit(EventKind::Snapshot, &key, Some(snap.next_round as u64), &[]);
                }
            }
            Err(e) => eprintln!("warning: snapshot write failed for `{label}`: {e}"),
        }
    };
    let mut log = trainer.run_with_snapshots(resume, campaign.snapshot_every, &mut sink);
    log.label = label.to_string();
    match store.save_result(cfg, label, &log) {
        Ok(()) => {
            if let Some(ev) = &events {
                ev.emit(
                    EventKind::Completed,
                    &key,
                    None,
                    &[
                        ("final_accuracy", log.final_accuracy),
                        ("pbar", log.pbar),
                        ("max_avg_power", log.max_avg_power()),
                        ("rounds", log.records.len() as f64),
                    ],
                );
            }
            if let Some(t) = &traces {
                t.mark("complete", &key, "", None);
            }
        }
        Err(e) => eprintln!("warning: result write failed for `{label}`: {e}"),
    }
    log
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Scheme};

    /// End-to-end delta execution: first invocation executes, the second is
    /// fully cache-served with identical trajectories.
    #[test]
    fn second_invocation_is_fully_cached() {
        let base = std::env::temp_dir().join("ota_scheduler_cache_test");
        let _ = std::fs::remove_dir_all(&base);
        let spec = || {
            let mut cfg = presets::smoke();
            cfg.iterations = 3;
            cfg.eval_every = 1;
            cfg.scheme = Scheme::ErrorFree;
            ExperimentSpec {
                id: "tsched".into(),
                title: "scheduler cache".into(),
                runs: vec![("error-free".into(), cfg)],
            }
        };
        let campaign = CampaignConfig {
            snapshot_every: 1,
            store_dir: base.join("store").to_str().unwrap().to_string(),
            ..CampaignConfig::default()
        };
        let out1 = base.join("out1");
        let out2 = base.join("out2");
        let (logs1, rep1) =
            run_experiment_cached(&spec(), out1.to_str().unwrap(), false, &campaign);
        assert_eq!(rep1, CampaignReport { executed: 1, resumed: 0, cached: 0 });
        let (logs2, rep2) =
            run_experiment_cached(&spec(), out2.to_str().unwrap(), false, &campaign);
        assert_eq!(rep2, CampaignReport { executed: 0, resumed: 0, cached: 1 });
        let series = |logs: &[TrainLog]| {
            logs[0]
                .records
                .iter()
                .map(|r| r.grad_norm.to_bits())
                .collect::<Vec<_>>()
        };
        assert_eq!(series(&logs1), series(&logs2));
        std::fs::remove_dir_all(&base).ok();
    }

    /// The `repro train` checkpointing path: first call executes and
    /// caches, the second is served from the store, and a corrupted result
    /// blob triggers a quiet recompute with the identical trajectory.
    #[test]
    fn single_run_caches_and_survives_corruption() {
        let base = std::env::temp_dir().join("ota_scheduler_single_test");
        let _ = std::fs::remove_dir_all(&base);
        let mut cfg = presets::smoke();
        cfg.iterations = 3;
        cfg.eval_every = 1;
        cfg.scheme = Scheme::ErrorFree;
        let campaign = CampaignConfig {
            snapshot_every: 1,
            store_dir: base.join("store").to_str().unwrap().to_string(),
            ..CampaignConfig::default()
        };
        let out = base.join("out").to_str().unwrap().to_string();
        let (log1, d1) = run_single_cached("solo", &cfg, &out, false, &campaign);
        assert_eq!(d1, RunDisposition::Executed);
        let (log2, d2) = run_single_cached("solo", &cfg, &out, false, &campaign);
        assert_eq!(d2, RunDisposition::Cached);
        let series = |log: &TrainLog| {
            log.records.iter().map(|r| r.grad_norm.to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(series(&log1), series(&log2));

        // Flip a bit in the cached result: the next invocation must
        // quarantine it, recompute, and land on the same trajectory.
        let entry = base
            .join("store")
            .join(crate::campaign::store::cache_key(&cfg))
            .join("result.bin");
        let mut bytes = std::fs::read(&entry).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x20;
        std::fs::write(&entry, &bytes).unwrap();
        let (log3, d3) = run_single_cached("solo", &cfg, &out, false, &campaign);
        assert_ne!(d3, RunDisposition::Cached, "corrupt result must not serve");
        assert_eq!(series(&log1), series(&log3));
        std::fs::remove_dir_all(&base).ok();
    }
}
