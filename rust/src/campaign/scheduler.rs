//! Cache-aware campaign execution in front of [`crate::experiments::runner`].
//!
//! For every run in an [`ExperimentSpec`] the scheduler consults the
//! content-addressed [`RunStore`] and executes only the delta:
//!
//! * **complete** — the cached [`TrainLog`] is loaded; nothing executes.
//! * **partial** — the latest [`TrainerSnapshot`] is restored and only the
//!   remaining rounds run (bit-identical to never having stopped).
//! * **absent** — the run executes from scratch, snapshotting every
//!   `snapshot_every` rounds so a crash costs at most one interval.
//!
//! Output files go through [`runner::write_outputs`], so a fully-cached
//! invocation regenerates `summary.csv` and the per-run CSVs byte-identical
//! to the original execution (asserted in `rust/tests/campaign_cache.rs`).

use crate::config::{CampaignConfig, RunConfig};
use crate::coordinator::{link, LinkScheme, TrainLog, Trainer};
use crate::experiments::runner::{self, ExperimentSpec};
use crate::model::PARAM_DIM;
use crate::util::threadpool::{default_workers, par_map};

use super::snapshot::{SnapshotReader, TrainerSnapshot};
use super::store::RunStore;

/// What the scheduler did with a spec's runs (the cache test's execution
/// counter).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CampaignReport {
    /// Runs executed from round 0.
    pub executed: usize,
    /// Runs resumed from a snapshot (counted separately from `executed`).
    pub resumed: usize,
    /// Runs served entirely from the cache.
    pub cached: usize,
}

enum Plan {
    Cached(TrainLog),
    Resume(TrainerSnapshot),
    Fresh,
}

/// Execute a spec through the run store. Returns the logs (in spec order,
/// labels applied) plus the execution report.
pub fn run_experiment_cached(
    spec: &ExperimentSpec,
    out_dir: &str,
    verbose: bool,
    campaign: &CampaignConfig,
) -> (Vec<TrainLog>, CampaignReport) {
    let store_dir = campaign.store_dir_or(out_dir);
    let store = RunStore::open(&store_dir).expect("open campaign run store");
    println!("\n### {} — {} [store: {store_dir}]", spec.id, spec.title);

    let plan: Vec<Plan> = spec
        .runs
        .iter()
        .map(|(label, cfg)| {
            if let Some(log) = store.load_result(cfg) {
                return Plan::Cached(log);
            }
            if campaign.resume {
                if let Some(snap) = store.load_snapshot(cfg) {
                    if snapshot_restorable(cfg, &snap) {
                        return Plan::Resume(snap);
                    }
                    eprintln!(
                        "warning: stored snapshot for `{}` does not restore cleanly; \
                         re-running from scratch",
                        label
                    );
                }
            }
            Plan::Fresh
        })
        .collect();

    let mut report = CampaignReport::default();
    for (step, (label, cfg)) in plan.iter().zip(&spec.runs) {
        match step {
            Plan::Cached(_) => {
                report.cached += 1;
                println!("--- run `{label}`: cached ({})", cfg.summary());
            }
            Plan::Resume(snap) => {
                report.resumed += 1;
                println!(
                    "--- run `{label}` [{} link]: resuming round {}/{} — {}",
                    cfg.scheme.kind().name(),
                    snap.next_round,
                    cfg.iterations,
                    cfg.summary()
                );
            }
            Plan::Fresh => {
                report.executed += 1;
                runner::print_run_header(label, cfg);
            }
        }
    }

    // Execute the delta — parallel across runs when quiet, like the
    // plain runner (cached entries are free either way).
    let workers = if verbose {
        1
    } else {
        default_workers(spec.runs.len())
    };
    let logs: Vec<TrainLog> = par_map(spec.runs.len(), workers, |i| {
        let (label, cfg) = &spec.runs[i];
        match &plan[i] {
            Plan::Cached(log) => {
                let mut log = log.clone();
                log.label = label.clone();
                log
            }
            Plan::Resume(snap) => execute(&store, label, cfg, Some(snap), campaign, verbose),
            Plan::Fresh => execute(&store, label, cfg, None, campaign, verbose),
        }
    });

    runner::write_outputs(spec, &logs, out_dir);
    (logs, report)
}

/// Pre-flight a stored snapshot: the trainer's restore path panics on a
/// blob it cannot apply (honest for a direct `Trainer::resume`, fatal for
/// a campaign), so the scheduler proves the link state restores into a
/// freshly built link first and falls back to a fresh run otherwise. The
/// extra link construction is paid only on actual resumes — cheap next to
/// losing the whole campaign to one torn blob.
fn snapshot_restorable(cfg: &RunConfig, snap: &TrainerSnapshot) -> bool {
    if snap.params.len() != PARAM_DIM
        || snap.optim_m.len() != PARAM_DIM
        || snap.optim_v.len() != PARAM_DIM
        || snap.next_round > cfg.iterations
        || snap.records.len() != snap.next_round
    {
        return false;
    }
    let mut probe = link::for_config(cfg, PARAM_DIM);
    probe.restore(&mut SnapshotReader::new(&snap.link)).is_ok()
}

fn execute(
    store: &RunStore,
    label: &str,
    cfg: &RunConfig,
    resume: Option<&TrainerSnapshot>,
    campaign: &CampaignConfig,
    verbose: bool,
) -> TrainLog {
    cfg.validate(PARAM_DIM).expect("invalid experiment config");
    let mut trainer = Trainer::new(cfg.clone()).expect("trainer construction");
    trainer.verbose = verbose;
    let mut sink = |snap: &TrainerSnapshot| {
        // A failed snapshot write must not kill the run it protects.
        if let Err(e) = store.save_snapshot(cfg, label, snap) {
            eprintln!("warning: snapshot write failed for `{label}`: {e}");
        }
    };
    let mut log = trainer.run_with_snapshots(resume, campaign.snapshot_every, &mut sink);
    log.label = label.to_string();
    if let Err(e) = store.save_result(cfg, label, &log) {
        eprintln!("warning: result write failed for `{label}`: {e}");
    }
    log
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Scheme};

    /// End-to-end delta execution: first invocation executes, the second is
    /// fully cache-served with identical trajectories.
    #[test]
    fn second_invocation_is_fully_cached() {
        let base = std::env::temp_dir().join("ota_scheduler_cache_test");
        let _ = std::fs::remove_dir_all(&base);
        let spec = || {
            let mut cfg = presets::smoke();
            cfg.iterations = 3;
            cfg.eval_every = 1;
            cfg.scheme = Scheme::ErrorFree;
            ExperimentSpec {
                id: "tsched".into(),
                title: "scheduler cache".into(),
                runs: vec![("error-free".into(), cfg)],
            }
        };
        let campaign = CampaignConfig {
            snapshot_every: 1,
            store_dir: base.join("store").to_str().unwrap().to_string(),
            resume: true,
            enabled: true,
        };
        let out1 = base.join("out1");
        let out2 = base.join("out2");
        let (logs1, rep1) =
            run_experiment_cached(&spec(), out1.to_str().unwrap(), false, &campaign);
        assert_eq!(rep1, CampaignReport { executed: 1, resumed: 0, cached: 0 });
        let (logs2, rep2) =
            run_experiment_cached(&spec(), out2.to_str().unwrap(), false, &campaign);
        assert_eq!(rep2, CampaignReport { executed: 0, resumed: 0, cached: 1 });
        let series = |logs: &[TrainLog]| {
            logs[0]
                .records
                .iter()
                .map(|r| r.grad_norm.to_bits())
                .collect::<Vec<_>>()
        };
        assert_eq!(series(&logs1), series(&logs2));
        std::fs::remove_dir_all(&base).ok();
    }
}
