//! Versioned binary snapshots of the complete trainer state.
//!
//! # Format
//!
//! Every blob starts with the 8-byte magic `OTACAMP1`, a little-endian
//! `u32` format version ([`SNAPSHOT_VERSION`]), and a one-byte kind tag
//! (trainer snapshot vs finished-run result), and ends with a trailing
//! FNV-1a 64 checksum over everything before it — corruption that happens
//! to preserve the framing must still fail loudly rather than resume a
//! silently different trajectory. Everything between header and checksum
//! is a flat little-endian stream written by [`SnapshotWriter`] and read
//! back by [`SnapshotReader`]; floats are serialized via `to_bits`, so a
//! round-trip is bit-exact including NaN payloads (the metrics layer uses
//! NaN as "not evaluated this round").
//!
//! A version bump is required whenever the byte layout changes — readers
//! reject other versions outright ([`SnapshotError::UnsupportedVersion`])
//! rather than guessing, because a mis-restored RNG position would produce
//! a silently *different* trajectory, which is worse than a hard error.
//!
//! # What a trainer snapshot contains
//!
//! [`TrainerSnapshot`] captures every piece of state that evolves across
//! rounds: the model weights θ_t, the PS optimizer moments (Adam m/v/t),
//! the partial [`TrainLog`] records, and an opaque per-link blob written by
//! [`LinkScheme::snapshot`] — error accumulators (analog and digital),
//! advancing RNG stream positions (MAC noise, QSGD stochastic rounding,
//! D2D broadcast noise), power-meter energy totals, and for decentralized
//! links the per-device model replicas plus their local optimizers.
//! Counter-based generators (fading gains, AR(1) chains, participation
//! subsets, straggler latencies) are pure in `(seed, device, t)` and
//! therefore *not* stored — they resume for free, which is what makes
//! bit-identical resume tractable at all.
//!
//! The snapshot also records [`TrainerSnapshot::config_hash`], the stable
//! hash of the canonicalized `RunConfig` (see [`super::store`]); restoring
//! under a different config is refused.
//!
//! [`LinkScheme::snapshot`]: crate::coordinator::link::LinkScheme::snapshot
//! [`TrainLog`]: crate::coordinator::TrainLog

use crate::channel::PowerMeter;
use crate::coordinator::link::ParticipationStats;
use crate::coordinator::{RoundRecord, TrainLog};

/// 8-byte magic prefix of every campaign blob.
pub const MAGIC: &[u8; 8] = b"OTACAMP1";

/// Binary format version; bump on any layout change.
pub const SNAPSHOT_VERSION: u32 = 1;

const KIND_SNAPSHOT: u8 = 1;
const KIND_RESULT: u8 = 2;

/// Raw PCG state for checkpointing: `(state, inc, cached spare normal)`.
pub type RngState = (u64, u64, Option<f64>);

/// Errors surfaced while decoding a snapshot blob.
#[derive(Debug)]
pub enum SnapshotError {
    /// The blob ended before the expected field.
    Truncated,
    /// The magic prefix is missing — not a campaign blob.
    BadMagic,
    /// Written by a different (incompatible) format version.
    UnsupportedVersion(u32),
    /// Structurally decodable but semantically wrong (length mismatch,
    /// wrong kind tag, config-hash mismatch, …).
    Corrupt(String),
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::Truncated => write!(f, "snapshot truncated"),
            SnapshotError::BadMagic => write!(f, "not a campaign snapshot (bad magic)"),
            SnapshotError::UnsupportedVersion(v) => {
                write!(f, "unsupported snapshot version {v} (expected {SNAPSHOT_VERSION})")
            }
            SnapshotError::Corrupt(msg) => write!(f, "corrupt snapshot: {msg}"),
        }
    }
}

impl std::error::Error for SnapshotError {}

/// Little-endian binary writer backing every snapshot blob.
#[derive(Default)]
pub struct SnapshotWriter {
    buf: Vec<u8>,
}

impl SnapshotWriter {
    pub fn new() -> SnapshotWriter {
        SnapshotWriter::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Raw bytes with no length prefix (header magic only).
    fn raw(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    /// Length-prefixed byte block.
    pub fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.raw(b);
    }

    pub fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
    }

    pub fn vec_f32(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f32(x);
        }
    }

    pub fn vec_f64(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f64(x);
        }
    }

    pub fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
            None => self.u8(0),
        }
    }
}

/// Cursor over a snapshot blob; every accessor checks bounds.
pub struct SnapshotReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> SnapshotReader<'a> {
    pub fn new(buf: &'a [u8]) -> SnapshotReader<'a> {
        SnapshotReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        if self.remaining() < n {
            return Err(SnapshotError::Truncated);
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8, SnapshotError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, SnapshotError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, SnapshotError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn f32(&mut self) -> Result<f32, SnapshotError> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64, SnapshotError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn bool(&mut self) -> Result<bool, SnapshotError> {
        Ok(self.u8()? != 0)
    }

    /// Read a length prefix, sanity-capped against the bytes that could
    /// possibly back it (each element at least `elem_bytes` wide), so a
    /// corrupt length cannot trigger a huge allocation.
    fn checked_len(&mut self, elem_bytes: usize) -> Result<usize, SnapshotError> {
        let len = self.u64()? as usize;
        if len.saturating_mul(elem_bytes) > self.remaining() {
            Err(SnapshotError::Truncated)
        } else {
            Ok(len)
        }
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>, SnapshotError> {
        let len = self.checked_len(1)?;
        Ok(self.take(len)?.to_vec())
    }

    pub fn str(&mut self) -> Result<String, SnapshotError> {
        String::from_utf8(self.bytes()?)
            .map_err(|_| SnapshotError::Corrupt("invalid utf-8 string".into()))
    }

    pub fn vec_f32(&mut self) -> Result<Vec<f32>, SnapshotError> {
        let len = self.checked_len(4)?;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    pub fn vec_f64(&mut self) -> Result<Vec<f64>, SnapshotError> {
        let len = self.checked_len(8)?;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    pub fn opt_f64(&mut self) -> Result<Option<f64>, SnapshotError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.f64()?)),
            other => Err(SnapshotError::Corrupt(format!("bad option tag {other}"))),
        }
    }
}

/// Serialize an advancing RNG position (see [`crate::util::rng::Pcg64::raw_state`]).
pub fn write_rng(w: &mut SnapshotWriter, st: RngState) {
    w.u64(st.0);
    w.u64(st.1);
    w.opt_f64(st.2);
}

pub fn read_rng(r: &mut SnapshotReader<'_>) -> Result<RngState, SnapshotError> {
    Ok((r.u64()?, r.u64()?, r.opt_f64()?))
}

/// Serialize a power meter's accumulated per-device energy + round count.
pub fn write_meter(w: &mut SnapshotWriter, meter: &PowerMeter) {
    w.vec_f64(meter.energy());
    w.u64(meter.rounds() as u64);
}

pub fn read_meter(r: &mut SnapshotReader<'_>, meter: &mut PowerMeter) -> Result<(), SnapshotError> {
    let energy = r.vec_f64()?;
    let rounds = r.u64()? as usize;
    if energy.len() != meter.devices() {
        return Err(SnapshotError::Corrupt(format!(
            "meter device count {} != configured {}",
            energy.len(),
            meter.devices()
        )));
    }
    meter.load(&energy, rounds);
    Ok(())
}

/// FNV-1a 64 — the checksum/hash primitive shared with the store's
/// config-addressing.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Append the trailing checksum to a finished blob body.
fn seal(mut bytes: Vec<u8>) -> Vec<u8> {
    let sum = fnv1a64(&bytes);
    bytes.extend_from_slice(&sum.to_le_bytes());
    bytes
}

/// Verify and strip the trailing checksum, returning the body.
fn unseal(bytes: &[u8]) -> Result<&[u8], SnapshotError> {
    if bytes.len() < 8 {
        return Err(SnapshotError::Truncated);
    }
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().expect("8-byte tail"));
    if fnv1a64(body) != want {
        return Err(SnapshotError::Corrupt("checksum mismatch".into()));
    }
    Ok(body)
}

fn write_header(w: &mut SnapshotWriter, kind: u8) {
    w.raw(MAGIC);
    w.u32(SNAPSHOT_VERSION);
    w.u8(kind);
}

fn read_header(r: &mut SnapshotReader<'_>, want_kind: u8) -> Result<(), SnapshotError> {
    if r.take(MAGIC.len())? != MAGIC {
        return Err(SnapshotError::BadMagic);
    }
    let version = r.u32()?;
    if version != SNAPSHOT_VERSION {
        return Err(SnapshotError::UnsupportedVersion(version));
    }
    let kind = r.u8()?;
    if kind != want_kind {
        return Err(SnapshotError::Corrupt(format!(
            "blob kind {kind} where {want_kind} was expected"
        )));
    }
    Ok(())
}

fn write_record(w: &mut SnapshotWriter, rec: &RoundRecord) {
    w.u64(rec.iter as u64);
    w.f64(rec.test_accuracy);
    w.f64(rec.train_loss);
    w.f64(rec.grad_norm);
    w.f64(rec.bits_per_device);
    w.f64(rec.p_t);
    w.u64(rec.amp_iterations as u64);
    w.f64(rec.accumulator_norm);
    w.f64(rec.round_secs);
    match rec.participation {
        Some(p) => {
            w.u8(1);
            w.u64(p.transmitting as u64);
            w.u64(p.not_scheduled as u64);
            w.u64(p.silenced_low_gain as u64);
            w.u64(p.dropped_stragglers as u64);
        }
        None => w.u8(0),
    }
    w.opt_f64(rec.consensus_distance);
}

fn read_record(r: &mut SnapshotReader<'_>) -> Result<RoundRecord, SnapshotError> {
    let iter = r.u64()? as usize;
    let test_accuracy = r.f64()?;
    let train_loss = r.f64()?;
    let grad_norm = r.f64()?;
    let bits_per_device = r.f64()?;
    let p_t = r.f64()?;
    let amp_iterations = r.u64()? as usize;
    let accumulator_norm = r.f64()?;
    let round_secs = r.f64()?;
    let participation = match r.u8()? {
        0 => None,
        1 => Some(ParticipationStats {
            transmitting: r.u64()? as usize,
            not_scheduled: r.u64()? as usize,
            silenced_low_gain: r.u64()? as usize,
            dropped_stragglers: r.u64()? as usize,
        }),
        other => return Err(SnapshotError::Corrupt(format!("bad participation tag {other}"))),
    };
    let consensus_distance = r.opt_f64()?;
    Ok(RoundRecord {
        iter,
        test_accuracy,
        train_loss,
        grad_norm,
        bits_per_device,
        p_t,
        amp_iterations,
        accumulator_norm,
        round_secs,
        participation,
        consensus_distance,
    })
}

fn write_records(w: &mut SnapshotWriter, records: &[RoundRecord]) {
    w.u64(records.len() as u64);
    for rec in records {
        write_record(w, rec);
    }
}

fn read_records(r: &mut SnapshotReader<'_>) -> Result<Vec<RoundRecord>, SnapshotError> {
    let len = r.u64()? as usize;
    // Each record is at least 9 fixed f64/u64 fields + 2 tag bytes.
    if len.saturating_mul(74) > r.remaining() {
        return Err(SnapshotError::Truncated);
    }
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        out.push(read_record(r)?);
    }
    Ok(out)
}

/// The complete mutable state of a [`crate::coordinator::Trainer`] between
/// two rounds: restore it into a freshly-built trainer for the same
/// `RunConfig` and the remaining rounds replay bit-identically to the
/// uninterrupted run.
#[derive(Clone, Debug)]
pub struct TrainerSnapshot {
    /// Stable hash of the canonicalized config this state belongs to
    /// ([`super::store::config_hash`]); resuming under any other config is
    /// refused.
    pub config_hash: u64,
    /// The next round index to execute (`t` rounds are already inside this
    /// snapshot; equals `iterations` for a finished run).
    pub next_round: usize,
    /// Model weights θ_t (the consensus/evaluation model for replica links).
    pub params: Vec<f32>,
    /// PS optimizer first moment (empty for stateless optimizers).
    pub optim_m: Vec<f32>,
    /// PS optimizer second moment.
    pub optim_v: Vec<f32>,
    /// PS optimizer step count.
    pub optim_t: u64,
    /// Opaque link-scheme state written by
    /// [`crate::coordinator::link::LinkScheme::snapshot`].
    pub link: Vec<u8>,
    /// Per-round records of the rounds already run (so a resumed run's log
    /// is the *complete* series, not a suffix).
    pub records: Vec<RoundRecord>,
    /// Last evaluated test accuracy so far.
    pub final_accuracy: f64,
}

impl TrainerSnapshot {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = SnapshotWriter::new();
        write_header(&mut w, KIND_SNAPSHOT);
        w.u64(self.config_hash);
        w.u64(self.next_round as u64);
        w.vec_f32(&self.params);
        w.vec_f32(&self.optim_m);
        w.vec_f32(&self.optim_v);
        w.u64(self.optim_t);
        w.bytes(&self.link);
        write_records(&mut w, &self.records);
        w.f64(self.final_accuracy);
        seal(w.into_bytes())
    }

    pub fn decode(bytes: &[u8]) -> Result<TrainerSnapshot, SnapshotError> {
        let mut r = SnapshotReader::new(unseal(bytes)?);
        read_header(&mut r, KIND_SNAPSHOT)?;
        let config_hash = r.u64()?;
        let next_round = r.u64()? as usize;
        let params = r.vec_f32()?;
        let optim_m = r.vec_f32()?;
        let optim_v = r.vec_f32()?;
        let optim_t = r.u64()?;
        let link = r.bytes()?;
        let records = read_records(&mut r)?;
        let final_accuracy = r.f64()?;
        Ok(TrainerSnapshot {
            config_hash,
            next_round,
            params,
            optim_m,
            optim_v,
            optim_t,
            link,
            records,
            final_accuracy,
        })
    }
}

/// Serialize a finished run's [`TrainLog`] (the run-cache result blob).
/// Round-trips bit-exactly — `round_secs` included — so CSVs regenerated
/// from the cache are byte-identical to the originals.
pub fn encode_log(log: &TrainLog) -> Vec<u8> {
    let mut w = SnapshotWriter::new();
    write_header(&mut w, KIND_RESULT);
    w.str(&log.label);
    w.f64(log.pbar);
    w.f64(log.final_accuracy);
    w.f64(log.total_secs);
    w.vec_f64(&log.measured_avg_power);
    write_records(&mut w, &log.records);
    seal(w.into_bytes())
}

pub fn decode_log(bytes: &[u8]) -> Result<TrainLog, SnapshotError> {
    let mut r = SnapshotReader::new(unseal(bytes)?);
    read_header(&mut r, KIND_RESULT)?;
    let label = r.str()?;
    let pbar = r.f64()?;
    let final_accuracy = r.f64()?;
    let total_secs = r.f64()?;
    let measured_avg_power = r.vec_f64()?;
    let records = read_records(&mut r)?;
    Ok(TrainLog {
        label,
        records,
        measured_avg_power,
        pbar,
        final_accuracy,
        total_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_roundtrip() {
        let mut w = SnapshotWriter::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.f32(-0.5);
        w.f64(f64::NAN);
        w.bool(true);
        w.str("über-label");
        w.vec_f32(&[1.0, -2.5]);
        w.vec_f64(&[3.25]);
        w.opt_f64(None);
        w.opt_f64(Some(9.0));
        let bytes = w.into_bytes();
        let mut r = SnapshotReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap(), -0.5);
        // NaN round-trips bit-exactly (to_bits framing).
        assert!(r.f64().unwrap().is_nan());
        assert!(r.bool().unwrap());
        assert_eq!(r.str().unwrap(), "über-label");
        assert_eq!(r.vec_f32().unwrap(), vec![1.0, -2.5]);
        assert_eq!(r.vec_f64().unwrap(), vec![3.25]);
        assert_eq!(r.opt_f64().unwrap(), None);
        assert_eq!(r.opt_f64().unwrap(), Some(9.0));
        assert_eq!(r.remaining(), 0);
        assert!(matches!(r.u8(), Err(SnapshotError::Truncated)));
    }

    fn sample_records() -> Vec<RoundRecord> {
        vec![
            RoundRecord {
                iter: 0,
                test_accuracy: 0.5,
                train_loss: 1.25,
                grad_norm: 0.75,
                bits_per_device: 128.0,
                p_t: 500.0,
                amp_iterations: 4,
                accumulator_norm: 0.125,
                round_secs: 0.01,
                participation: Some(ParticipationStats {
                    transmitting: 3,
                    not_scheduled: 1,
                    silenced_low_gain: 2,
                    dropped_stragglers: 0,
                }),
                consensus_distance: Some(0.0),
            },
            RoundRecord {
                iter: 1,
                test_accuracy: f64::NAN,
                train_loss: f64::NAN,
                grad_norm: 0.5,
                bits_per_device: 0.0,
                p_t: 250.0,
                amp_iterations: 0,
                accumulator_norm: 0.0,
                round_secs: 0.02,
                participation: None,
                consensus_distance: None,
            },
        ]
    }

    #[test]
    fn trainer_snapshot_roundtrip() {
        let snap = TrainerSnapshot {
            config_hash: 0xABCD_EF01_2345_6789,
            next_round: 42,
            params: vec![0.5, -1.0, 3.0],
            optim_m: vec![0.1; 3],
            optim_v: vec![0.2; 3],
            optim_t: 42,
            link: vec![1, 2, 3, 4],
            records: sample_records(),
            final_accuracy: 0.5,
        };
        let back = TrainerSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(back.config_hash, snap.config_hash);
        assert_eq!(back.next_round, 42);
        assert_eq!(back.params, snap.params);
        assert_eq!(back.optim_m, snap.optim_m);
        assert_eq!(back.optim_v, snap.optim_v);
        assert_eq!(back.optim_t, 42);
        assert_eq!(back.link, snap.link);
        assert_eq!(back.records.len(), 2);
        assert_eq!(back.records[0].participation, snap.records[0].participation);
        assert!(back.records[1].test_accuracy.is_nan());
        assert_eq!(back.final_accuracy, 0.5);
    }

    #[test]
    fn log_roundtrip_is_bit_exact() {
        let log = TrainLog {
            label: "D-DSGD LH".into(),
            records: sample_records(),
            measured_avg_power: vec![499.5, 500.0],
            pbar: 500.0,
            final_accuracy: 0.5,
            total_secs: 1.5,
        };
        let back = decode_log(&encode_log(&log)).unwrap();
        assert_eq!(back.label, log.label);
        assert_eq!(back.pbar.to_bits(), log.pbar.to_bits());
        assert_eq!(back.total_secs.to_bits(), log.total_secs.to_bits());
        assert_eq!(back.measured_avg_power, log.measured_avg_power);
        assert_eq!(back.records.len(), log.records.len());
        for (a, b) in back.records.iter().zip(&log.records) {
            assert_eq!(a.round_secs.to_bits(), b.round_secs.to_bits());
            assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits());
        }
    }

    /// Re-seal a tampered blob so the test reaches the check *behind* the
    /// checksum (header validation order: checksum → magic → version).
    fn reseal(bytes: &mut Vec<u8>) {
        let n = bytes.len();
        let sum = fnv1a64(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
    }

    #[test]
    fn bad_magic_version_and_corruption_rejected() {
        let log = TrainLog {
            label: "x".into(),
            records: sample_records(),
            measured_avg_power: vec![1.0],
            pbar: 1.0,
            final_accuracy: 0.0,
            total_secs: 0.0,
        };
        let mut bytes = encode_log(&log);
        // Kind mismatch: a result blob is not a trainer snapshot.
        assert!(matches!(
            TrainerSnapshot::decode(&bytes),
            Err(SnapshotError::Corrupt(_))
        ));
        // Version bump rejected (checksum fixed up so the version gate is
        // what fires).
        bytes[8] = 99;
        reseal(&mut bytes);
        assert!(matches!(
            decode_log(&bytes),
            Err(SnapshotError::UnsupportedVersion(99))
        ));
        // Magic damage rejected.
        bytes[0] = b'X';
        reseal(&mut bytes);
        assert!(matches!(decode_log(&bytes), Err(SnapshotError::BadMagic)));
        // Truncation trips the checksum.
        let ok = encode_log(&log);
        assert!(decode_log(&ok[..ok.len() - 1]).is_err());
        // Framing-preserving corruption in the middle of the payload is
        // caught by the trailing checksum — never a silent wrong resume.
        let mut flipped = encode_log(&log);
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x40;
        assert!(matches!(
            decode_log(&flipped),
            Err(SnapshotError::Corrupt(_))
        ));
    }
}
