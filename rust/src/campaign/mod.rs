//! Campaign orchestration: deterministic checkpoint/resume and a
//! content-addressed run cache for large experiment sweeps.
//!
//! The paper's figures are sweeps of hundreds of multi-thousand-round DSGD
//! runs; at production scale a campaign must survive interruption and must
//! not recompute what it already knows. This subsystem supplies both:
//!
//! * [`snapshot`] — versioned binary snapshots of the complete trainer
//!   state (model weights, Adam moments, per-device error accumulators,
//!   advancing RNG stream positions, D2D replicas and local optimizers,
//!   power-meter totals, the partial round log). Every
//!   [`LinkScheme`](crate::coordinator::link::LinkScheme) implements the
//!   `snapshot`/`restore` hook pair, and because all scenario randomness is
//!   either counter-based (pure per `(seed, device, t)`: fading gains,
//!   AR(1) chains, participation subsets, straggler latencies) or captured
//!   as an explicit RNG position (MAC noise, QSGD rounding, D2D broadcast
//!   noise), a resumed run replays **bit-identically** to the
//!   uninterrupted trajectory — grad norms, weights, telemetry, Eq. 6
//!   audit and all. Pinned for every factory scheme in
//!   `rust/tests/campaign_resume.rs`.
//!
//! * [`store`] — a content-addressed store keyed by a stable FNV-1a hash
//!   of the *canonicalized* `RunConfig` (every field, fixed order,
//!   canonical enum spellings; see `store::canonical_config` for the
//!   rules). Entries hold the latest snapshot while a run is partial and
//!   the bit-exact result log once complete; all writes are
//!   temp-file + rename so interruption never corrupts an entry.
//!
//! * [`manifest`] — the human-readable TOML index entry per store record
//!   (`repro status` reads these; the binary blobs remain the source of
//!   truth).
//!
//! * [`scheduler`] — fronts `experiments::runner`: re-invoking
//!   `repro fig <x>` loads completed runs from the cache, resumes partial
//!   ones from their latest snapshot, and executes only the delta, while
//!   producing byte-identical CSV outputs either way.
//!
//! # Store hygiene
//!
//! Blobs are checksummed; a truncated or bit-flipped blob is quarantined
//! on load and the run recomputed — one bad disk sector never aborts a
//! campaign. Partial entries retain the newest `keep_last_n` snapshot
//! rounds (`[campaign] keep_last_n`) so a torn latest snapshot falls back
//! a round instead of restarting, and `repro gc` prunes stores back to
//! that policy (complete entries drop all snapshot blobs outright).

pub mod manifest;
pub mod scheduler;
pub mod snapshot;
pub mod store;

pub use manifest::{RunManifest, RunStatus};
pub use scheduler::{run_experiment_cached, run_single_cached, CampaignReport, RunDisposition};
pub use snapshot::{SnapshotError, SnapshotReader, SnapshotWriter, TrainerSnapshot};
pub use store::{cache_key, config_hash, GcReport, RunStore};
