//! Per-run manifests: the human-readable index entry next to each store
//! blob, written in the same TOML subset `config::parser` reads back.
//!
//! The manifest is advisory metadata for `repro status` and store
//! inspection — the binary blobs are self-describing (magic + version +
//! config hash), so a lost or stale manifest can never corrupt a resume;
//! at worst the entry stops showing up in the status listing.

use std::path::Path;

use crate::config::parser;

/// Where a cached run stands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunStatus {
    /// A snapshot exists but the run has not finished; `repro resume`
    /// continues it from `snapshot_round`.
    Partial,
    /// The finished result is cached; re-running is a pure load.
    Complete,
}

impl RunStatus {
    pub fn name(&self) -> &'static str {
        match self {
            RunStatus::Partial => "partial",
            RunStatus::Complete => "complete",
        }
    }

    pub fn parse(s: &str) -> Option<RunStatus> {
        match s {
            "partial" => Some(RunStatus::Partial),
            "complete" => Some(RunStatus::Complete),
            _ => None,
        }
    }
}

/// One store entry's metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct RunManifest {
    /// Content-address: hex of the canonical config hash (the entry's
    /// directory name).
    pub key: String,
    /// Last run label this config executed under (labels are display
    /// metadata; the config hash is the identity).
    pub label: String,
    /// `RunConfig::summary()` echo for humans.
    pub summary: String,
    pub status: RunStatus,
    /// Round index the latest snapshot resumes from (== `iterations` once
    /// complete).
    pub snapshot_round: usize,
    /// Total rounds the config runs.
    pub iterations: usize,
    /// Snapshot format version of the blobs next to this manifest.
    pub version: u32,
}

/// Manifest fields are display metadata, sanitized lossily for the
/// escape-free TOML subset (shared rule: [`parser::sanitize_display`]).
fn clean(s: &str) -> String {
    parser::sanitize_display(s)
}

impl RunManifest {
    pub fn to_toml(&self) -> String {
        format!(
            "[manifest]\nkey = \"{}\"\nlabel = \"{}\"\nsummary = \"{}\"\nstatus = \"{}\"\nsnapshot_round = {}\niterations = {}\nversion = {}\n",
            clean(&self.key),
            clean(&self.label),
            clean(&self.summary),
            self.status.name(),
            self.snapshot_round,
            self.iterations,
            self.version,
        )
    }

    pub fn from_toml(text: &str) -> Result<RunManifest, String> {
        let doc = parser::parse(text).map_err(|e| e.to_string())?;
        let s = doc.get("manifest").ok_or("missing [manifest] section")?;
        let get_str = |k: &str| -> Result<String, String> {
            s.get(k)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .ok_or_else(|| format!("missing or non-string manifest key {k:?}"))
        };
        let get_usize = |k: &str| -> Result<usize, String> {
            s.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| format!("missing or non-integer manifest key {k:?}"))
        };
        let status_name = get_str("status")?;
        Ok(RunManifest {
            key: get_str("key")?,
            label: get_str("label")?,
            summary: get_str("summary")?,
            status: RunStatus::parse(&status_name)
                .ok_or_else(|| format!("unknown status {status_name:?}"))?,
            snapshot_round: get_usize("snapshot_round")?,
            iterations: get_usize("iterations")?,
            version: get_usize("version")? as u32,
        })
    }

    pub fn read(path: &Path) -> Result<RunManifest, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        RunManifest::from_toml(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunManifest {
        RunManifest {
            key: "00ff00ff00ff00ff".into(),
            label: "D-DSGD LH".into(),
            summary: "D-DSGD M=25 B=1000 s=3925 k=1962 P̄=200 σ²=1 T=300".into(),
            status: RunStatus::Partial,
            snapshot_round: 120,
            iterations: 300,
            version: 1,
        }
    }

    #[test]
    fn toml_roundtrip() {
        let m = sample();
        let back = RunManifest::from_toml(&m.to_toml()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn complete_status_roundtrip() {
        let m = RunManifest {
            status: RunStatus::Complete,
            snapshot_round: 300,
            ..sample()
        };
        assert_eq!(RunManifest::from_toml(&m.to_toml()).unwrap().status, RunStatus::Complete);
    }

    #[test]
    fn quotes_in_labels_survive_as_cleaned_text() {
        let m = RunManifest {
            label: "odd \"label\"".into(),
            ..sample()
        };
        let back = RunManifest::from_toml(&m.to_toml()).unwrap();
        assert_eq!(back.label, "odd 'label'");
    }

    #[test]
    fn missing_section_rejected() {
        assert!(RunManifest::from_toml("key = \"x\"\n").is_err());
        assert!(RunManifest::from_toml("[manifest]\nkey = \"x\"\n").is_err());
    }
}
