//! The content-addressed run store: results and snapshots keyed by a
//! stable hash of the canonicalized `RunConfig`.
//!
//! # Cache-key canonicalization
//!
//! [`canonical_config`] renders *every* `RunConfig` field as one
//! `key=value` line in a fixed order, using each enum's canonical string
//! form (`Scheme::name`, `FadingDist::describe`, …) and `f64` `Display`
//! (shortest round-trip form, so `500.0` and `500.00` collide as they
//! should). [`config_hash`] is FNV-1a 64 over those bytes and
//! [`cache_key`] its 16-hex-digit rendering — the store directory name.
//!
//! Two deliberate properties:
//!
//! * **Never a false hit.** Fields a scheme happens to ignore (e.g. the
//!   `[topology]` table under an error-free run) are still hashed, so the
//!   key is conservatively fine-grained: a config change can only ever
//!   *miss* the cache, never collide into the wrong entry.
//! * **Labels are not identity.** The experiment label is display metadata
//!   recorded in the manifest; renaming a run in a figure spec still hits
//!   the cache for the identical config.
//!
//! # Layout
//!
//! ```text
//! <store_dir>/<cache_key>/manifest.toml     # human-readable index entry
//! <store_dir>/<cache_key>/snapshot.bin      # latest TrainerSnapshot (partial runs)
//! <store_dir>/<cache_key>/snap_<round>.bin  # retained history (keep_last_n > 1)
//! <store_dir>/<cache_key>/result.bin        # finished TrainLog (complete runs)
//! <store_dir>/<cache_key>/*.corrupt         # quarantined blobs (kept for forensics)
//! <store_dir>/fleet/                        # worker-fleet queue + leases (see `crate::fleet`)
//! <store_dir>/fleet/events/<writer>.jsonl   # append-only telemetry log (see `crate::fleet::events`)
//! ```
//!
//! All writes go through a temp-file + rename, so a crash mid-write leaves
//! the previous blob intact — the whole point of the subsystem.
//!
//! # Corruption policy
//!
//! Every blob carries a trailing checksum (see [`super::snapshot`]). A blob
//! that fails to decode — truncated by a dying writer, bit-flipped by a bad
//! disk — is **quarantined** (renamed to `<name>.corrupt`) rather than left
//! in place, and the load reports a miss: the campaign recomputes that one
//! run instead of aborting, and the next write lands on the clean path. For
//! snapshots, [`RunStore::load_best_snapshot`] falls back through the
//! retained history before giving up, so a torn latest snapshot costs only
//! the rounds since the previous one.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::config::{Backend, DatasetSpec, RunConfig};
use crate::coordinator::TrainLog;
use crate::fleet::events::{EventKind, EventLog};

use super::manifest::{RunManifest, RunStatus};
use super::snapshot::{decode_log, encode_log, fnv1a64, TrainerSnapshot, SNAPSHOT_VERSION};

/// Render every config field in fixed order with canonical value forms.
/// The exhaustive destructuring (no `..`) is load-bearing: adding a field
/// to `RunConfig` without deciding its canonical rendering fails to
/// compile here, which is what keeps "never a false cache hit" true over
/// time.
pub fn canonical_config(cfg: &RunConfig) -> String {
    let RunConfig {
        scheme,
        devices,
        local_samples,
        channel_uses,
        sparsity,
        pbar,
        noise_var,
        iterations,
        power,
        lr,
        noniid,
        seed,
        mean_removal_rounds,
        qsgd_levels,
        backend,
        dataset,
        eval_every,
        amp_iters,
        amp_tol,
        amp_threshold_mult,
        fading,
        csi_threshold,
        participation,
        deadline_secs,
        latency_mean_secs,
        fading_rho,
        topology,
    } = cfg;
    let crate::config::TopologyConfig {
        family,
        degree,
        p,
        mixing,
        seed: topology_seed,
    } = topology;
    let backend = match backend {
        Backend::Rust => "rust",
        Backend::Pjrt => "pjrt",
    };
    let dataset = match dataset {
        DatasetSpec::Synthetic { train, test } => format!("synthetic:{train}:{test}"),
        DatasetSpec::MnistIdx { dir } => format!("mnist:{dir}"),
    };
    format!(
        "scheme={}\ndevices={devices}\nlocal_samples={local_samples}\nchannel_uses={channel_uses}\nsparsity={sparsity}\npbar={pbar}\nnoise_var={noise_var}\niterations={iterations}\npower={}\nlr={lr}\nnoniid={noniid}\nseed={seed}\nmean_removal_rounds={mean_removal_rounds}\nqsgd_levels={qsgd_levels}\nbackend={backend}\ndataset={dataset}\neval_every={eval_every}\namp_iters={amp_iters}\namp_tol={amp_tol}\namp_threshold_mult={amp_threshold_mult}\nfading={}\ncsi_threshold={csi_threshold}\nparticipation={}\ndeadline_secs={deadline_secs}\nlatency_mean_secs={latency_mean_secs}\nfading_rho={fading_rho}\ntopology_family={}\ntopology_degree={degree}\ntopology_p={p}\ntopology_mixing={}\ntopology_seed={topology_seed}\n",
        scheme.name(),
        power.name(),
        fading.describe(),
        participation.describe(),
        family.name(),
        mixing.name(),
    )
}

/// FNV-1a 64 over the canonical rendering — the run's stable identity.
pub fn config_hash(cfg: &RunConfig) -> u64 {
    fnv1a64(canonical_config(cfg).as_bytes())
}

/// The store address of a config: `config_hash` as 16 hex digits.
pub fn cache_key(cfg: &RunConfig) -> String {
    format!("{:016x}", config_hash(cfg))
}

/// Crash-safe write: temp file in the same directory, fsync'd before the
/// rename — without the sync, journaling filesystems may commit the
/// rename ahead of the data blocks and a power cut would leave a torn
/// blob where the previous good one used to be. The temp name is unique
/// per process *and* per write, so two campaigns sharing a store (or two
/// parallel workers hitting one entry) never interleave into the same
/// temp file; last rename wins with a complete blob either way.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> io::Result<()> {
    use std::io::Write as _;
    use std::sync::atomic::{AtomicU64, Ordering};
    static WRITE_SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = WRITE_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = path.with_extension(format!("tmp.{}.{seq}", std::process::id()));
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)
}

/// History-blob filename for a snapshot taken after `round` rounds;
/// zero-padded so lexicographic filename order is round order.
fn history_name(round: usize) -> String {
    format!("snap_{round:08}.bin")
}

/// The entry's retained history snapshots, newest round first.
fn history_snapshots(dir: &Path) -> Vec<(usize, PathBuf)> {
    let mut out: Vec<(usize, PathBuf)> = Vec::new();
    let Ok(entries) = fs::read_dir(dir) else {
        return out;
    };
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if let Some(round) = name
            .strip_prefix("snap_")
            .and_then(|s| s.strip_suffix(".bin"))
            .and_then(|s| s.parse::<usize>().ok())
        {
            out.push((round, entry.path()));
        }
    }
    out.sort_by(|a, b| b.0.cmp(&a.0));
    out
}

/// Move a blob that failed its checksum/decode out of the load path
/// (best-effort; a failed rename just leaves it to fail the same way next
/// time). The `.corrupt` file is kept for forensics until `repro gc`.
fn quarantine(path: &Path, why: &str) {
    let target = path.with_extension("bin.corrupt");
    eprintln!(
        "warning: quarantining corrupt campaign blob {} ({why}); the run will be recomputed",
        path.display()
    );
    let _ = fs::rename(path, &target);
}

/// Remove one file, crediting the reclaim report on success.
fn remove_counted(path: PathBuf, report: &mut GcReport) {
    if let Ok(meta) = fs::metadata(&path) {
        if fs::remove_file(&path).is_ok() {
            report.files_removed += 1;
            report.bytes_reclaimed += meta.len();
        }
    }
}

/// Whether a directory entry's mtime is older than `secs` (unreadable
/// mtimes count as fresh — never destroy on bad evidence).
fn older_than(entry: &fs::DirEntry, secs: u64) -> bool {
    entry
        .metadata()
        .and_then(|m| m.modified())
        .ok()
        .and_then(|m| std::time::SystemTime::now().duration_since(m).ok())
        .map(|age| age.as_secs() > secs)
        .unwrap_or(false)
}

/// Age gate for gc's stray sweeps: a `*.tmp.*` file younger than this may
/// be an in-flight atomic write racing the gc on a live store.
const GC_STRAY_MIN_AGE_SECS: u64 = 3600;

/// Sweep one entry directory's true garbage: quarantined blobs (their
/// forensic purpose is served) and aged-out write temps. Fresh temps are
/// left alone — they may be an in-flight atomic write racing this gc.
fn sweep_entry_strays(dir: &Path, report: &mut GcReport) {
    let Ok(files) = fs::read_dir(dir) else {
        return;
    };
    for f in files.flatten() {
        let name = f.file_name().to_string_lossy().into_owned();
        let stray = name.ends_with(".corrupt")
            || (name.contains(".tmp.") && older_than(&f, GC_STRAY_MIN_AGE_SECS));
        if stray {
            remove_counted(f.path(), report);
        }
    }
}

/// What [`RunStore::gc`] reclaimed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcReport {
    /// Store entries scanned (directories with a readable manifest).
    pub entries: usize,
    /// Files removed (snapshots, strays, quarantined blobs).
    pub files_removed: usize,
    /// Total bytes those files occupied.
    pub bytes_reclaimed: u64,
}

/// A directory of content-addressed run entries.
pub struct RunStore {
    root: PathBuf,
    /// Optional telemetry sink ([`crate::fleet::events`]); observe-only,
    /// attached by the scheduler / worker when telemetry is enabled.
    events: std::sync::Mutex<Option<EventLog>>,
    /// Optional span sink ([`crate::fleet::trace`]); observe-only,
    /// attached alongside the event log when tracing is enabled.
    traces: std::sync::Mutex<Option<crate::fleet::trace::TraceLog>>,
}

impl RunStore {
    /// Open (creating if needed) the store rooted at `dir`.
    pub fn open(dir: &str) -> io::Result<RunStore> {
        let root = PathBuf::from(dir);
        fs::create_dir_all(&root)?;
        Ok(RunStore {
            root,
            events: std::sync::Mutex::new(None),
            traces: std::sync::Mutex::new(None),
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Attach a telemetry event log: store operations (quarantines) and
    /// every layer holding this store emit through it. Telemetry is
    /// observe-only — nothing here changes what the store persists.
    pub fn attach_events(&self, log: EventLog) {
        *self.events.lock().unwrap_or_else(|e| e.into_inner()) = Some(log);
    }

    /// The attached event log, if any (cheap clone — all clones append
    /// to the same per-writer segment).
    pub fn event_log(&self) -> Option<EventLog> {
        self.events
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// Attach a span trace log ([`crate::fleet::trace`]): worker loop,
    /// queue, and scheduler spans for this store are appended through
    /// it. Observe-only, like the event log.
    pub fn attach_trace(&self, log: crate::fleet::trace::TraceLog) {
        *self.traces.lock().unwrap_or_else(|e| e.into_inner()) = Some(log);
    }

    /// The attached trace log, if any (cheap clone).
    pub fn trace_log(&self) -> Option<crate::fleet::trace::TraceLog> {
        self.traces
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// [`quarantine`] plus a `quarantined` telemetry event keyed by the
    /// store entry the blob belonged to.
    fn quarantine_blob(&self, path: &Path, why: &str) {
        quarantine(path, why);
        if let Some(log) = self.event_log() {
            let key = path
                .parent()
                .and_then(|p| p.file_name())
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default();
            log.emit(EventKind::Quarantined, &key, None, &[]);
        }
    }

    fn entry_dir(&self, cfg: &RunConfig) -> PathBuf {
        self.root.join(cache_key(cfg))
    }

    /// Whether a result blob is present for `cfg` — a single `stat`, no
    /// read or decode. The fleet worker's claim scan runs this per item
    /// per pass; [`RunStore::load_result`] (which decodes and verifies
    /// the checksum) stays the authority wherever the bytes are used.
    pub fn has_result(&self, cfg: &RunConfig) -> bool {
        self.entry_dir(cfg).join("result.bin").exists()
    }

    /// The finished result for `cfg`, if cached. A missing blob is a plain
    /// miss; a blob that fails its checksum or decode is **quarantined**
    /// (renamed `result.bin.corrupt`) and also reads as a miss — the run
    /// re-executes instead of the campaign aborting.
    pub fn load_result(&self, cfg: &RunConfig) -> Option<TrainLog> {
        let path = self.entry_dir(cfg).join("result.bin");
        let bytes = fs::read(&path).ok()?;
        match decode_log(&bytes) {
            Ok(log) => Some(log),
            Err(e) => {
                self.quarantine_blob(&path, &e.to_string());
                None
            }
        }
    }

    /// The latest snapshot for `cfg`, if one exists and belongs to this
    /// exact config (the embedded hash is checked on top of the address).
    /// Corrupt blobs are quarantined and read as a miss; use
    /// [`RunStore::load_best_snapshot`] to fall back through the retained
    /// history as well.
    pub fn load_snapshot(&self, cfg: &RunConfig) -> Option<TrainerSnapshot> {
        self.load_snapshot_at(cfg, &self.entry_dir(cfg).join("snapshot.bin"))
    }

    fn load_snapshot_at(&self, cfg: &RunConfig, path: &Path) -> Option<TrainerSnapshot> {
        let bytes = fs::read(path).ok()?;
        let snap = match TrainerSnapshot::decode(&bytes) {
            Ok(snap) => snap,
            Err(e) => {
                self.quarantine_blob(path, &e.to_string());
                return None;
            }
        };
        if snap.config_hash != config_hash(cfg) {
            return None;
        }
        Some(snap)
    }

    /// The newest restorable snapshot for `cfg`: the latest blob if it
    /// decodes, otherwise the retained history newest-first. Each corrupt
    /// blob encountered on the way is quarantined, so one torn write costs
    /// at most the rounds since the previous retained snapshot — never the
    /// whole run.
    pub fn load_best_snapshot(&self, cfg: &RunConfig) -> Option<TrainerSnapshot> {
        if let Some(snap) = self.load_snapshot(cfg) {
            return Some(snap);
        }
        for (_, path) in history_snapshots(&self.entry_dir(cfg)) {
            if let Some(snap) = self.load_snapshot_at(cfg, &path) {
                return Some(snap);
            }
        }
        None
    }

    /// Persist a mid-run snapshot and mark the entry partial (no retained
    /// history — the latest blob only).
    pub fn save_snapshot(
        &self,
        cfg: &RunConfig,
        label: &str,
        snap: &TrainerSnapshot,
    ) -> io::Result<()> {
        self.save_snapshot_retained(cfg, label, snap, 1)
    }

    /// Persist a mid-run snapshot, keep the newest `keep_last_n` distinct
    /// snapshot rounds for this entry, and mark the entry partial. With
    /// `keep_last_n <= 1` only `snapshot.bin` is written (the original
    /// layout); beyond that, history blobs `snap_<round>.bin` accumulate
    /// and older ones are pruned as new rounds land.
    pub fn save_snapshot_retained(
        &self,
        cfg: &RunConfig,
        label: &str,
        snap: &TrainerSnapshot,
        keep_last_n: usize,
    ) -> io::Result<()> {
        let dir = self.entry_dir(cfg);
        fs::create_dir_all(&dir)?;
        let encoded = snap.encode();
        write_atomic(&dir.join("snapshot.bin"), &encoded)?;
        if keep_last_n > 1 {
            write_atomic(&dir.join(history_name(snap.next_round)), &encoded)?;
            // The latest round lives in snapshot.bin *and* its history
            // blob (so a torn snapshot.bin still has a same-round twin);
            // prune history beyond the newest `keep_last_n` rounds.
            for (_, path) in history_snapshots(&dir).into_iter().skip(keep_last_n) {
                let _ = fs::remove_file(path);
            }
        }
        let manifest = RunManifest {
            key: cache_key(cfg),
            label: label.to_string(),
            summary: cfg.summary(),
            status: RunStatus::Partial,
            snapshot_round: snap.next_round,
            iterations: cfg.iterations,
            version: SNAPSHOT_VERSION,
        };
        write_atomic(&dir.join("manifest.toml"), manifest.to_toml().as_bytes())
    }

    /// Persist a finished run's log and mark the entry complete. The
    /// now-stale snapshot blob is dropped.
    pub fn save_result(&self, cfg: &RunConfig, label: &str, log: &TrainLog) -> io::Result<()> {
        let dir = self.entry_dir(cfg);
        fs::create_dir_all(&dir)?;
        write_atomic(&dir.join("result.bin"), &encode_log(log))?;
        let manifest = RunManifest {
            key: cache_key(cfg),
            label: label.to_string(),
            summary: cfg.summary(),
            status: RunStatus::Complete,
            snapshot_round: cfg.iterations,
            iterations: cfg.iterations,
            version: SNAPSHOT_VERSION,
        };
        write_atomic(&dir.join("manifest.toml"), manifest.to_toml().as_bytes())?;
        let _ = fs::remove_file(dir.join("snapshot.bin"));
        for (_, path) in history_snapshots(&dir) {
            let _ = fs::remove_file(path);
        }
        Ok(())
    }

    /// Prune the store to the retention policy: complete entries drop all
    /// snapshot blobs (the result supersedes them), partial entries keep
    /// only the newest `keep_last_n` history rounds, stray temp files
    /// plus quarantined blobs are removed everywhere, and aged-out
    /// temp/grave strays left in the fleet coordination dirs by killed
    /// workers are swept. Returns what was reclaimed.
    pub fn gc(&self, keep_last_n: usize) -> io::Result<GcReport> {
        let mut report = GcReport::default();
        let entries = fs::read_dir(&self.root)?;
        for entry in entries.flatten() {
            let dir = entry.path();
            if !dir.is_dir() {
                continue;
            }
            let Ok(manifest) = RunManifest::read(&dir.join("manifest.toml")) else {
                // No readable manifest: the blobs here may still be LIVE
                // cache state (blob writes land before the manifest write,
                // and loads never consult the manifest), so only true
                // garbage is swept — quarantined blobs and aged temps.
                sweep_entry_strays(&dir, &mut report);
                continue;
            };
            report.entries += 1;
            match manifest.status {
                RunStatus::Complete => {
                    remove_counted(dir.join("snapshot.bin"), &mut report);
                    for (_, path) in history_snapshots(&dir) {
                        remove_counted(path, &mut report);
                    }
                }
                RunStatus::Partial => {
                    for (_, path) in
                        history_snapshots(&dir).into_iter().skip(keep_last_n.max(1))
                    {
                        remove_counted(path, &mut report);
                    }
                }
            }
            sweep_entry_strays(&dir, &mut report);
        }
        // Fleet coordination strays: a worker SIGKILL'd mid-acquire leaves
        // `*.tmp.*` (pre-link record) or `*.stale.*` (stolen-lease grave)
        // files in the lease dir, and an interrupted enqueue leaves write
        // temps in the queue dir. Only visibly old ones are swept — a
        // fresh temp may be an in-flight acquire racing this very gc.
        for sub in ["leases", "queue"] {
            let dir = self.root.join("fleet").join(sub);
            let Ok(files) = fs::read_dir(&dir) else {
                continue;
            };
            for f in files.flatten() {
                let name = f.file_name().to_string_lossy().into_owned();
                let stray = name.contains(".tmp.") || name.contains(".stale.");
                if stray && older_than(&f, GC_STRAY_MIN_AGE_SECS) {
                    remove_counted(f.path(), &mut report);
                }
            }
        }
        Ok(report)
    }

    /// All readable manifests, sorted by key (deterministic listing for
    /// `repro status`). Unreadable entries are skipped, not fatal.
    pub fn list(&self) -> Vec<RunManifest> {
        let mut out = Vec::new();
        let Ok(entries) = fs::read_dir(&self.root) else {
            return out;
        };
        for entry in entries.flatten() {
            let manifest_path = entry.path().join("manifest.toml");
            if let Ok(m) = RunManifest::read(&manifest_path) {
                out.push(m);
            }
        }
        out.sort_by(|a, b| a.key.cmp(&b.key));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Scheme};

    fn tmp_store(name: &str) -> (RunStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!("ota_store_{name}"));
        let _ = fs::remove_dir_all(&dir);
        let store = RunStore::open(dir.to_str().unwrap()).unwrap();
        (store, dir)
    }

    #[test]
    fn hash_is_stable_and_field_sensitive() {
        let cfg = presets::smoke();
        assert_eq!(config_hash(&cfg), config_hash(&cfg.clone()));
        // Every semantically distinct knob must move the key.
        let variants = [
            RunConfig { seed: cfg.seed + 1, ..cfg.clone() },
            RunConfig { scheme: Scheme::DDsgd, ..cfg.clone() },
            RunConfig { iterations: cfg.iterations + 1, ..cfg.clone() },
            RunConfig { pbar: cfg.pbar * 2.0, ..cfg.clone() },
            RunConfig { fading_rho: 0.5, ..cfg.clone() },
            RunConfig { eval_every: cfg.eval_every + 1, ..cfg.clone() },
        ];
        let base = config_hash(&cfg);
        for v in &variants {
            assert_ne!(config_hash(v), base, "{}", canonical_config(v));
        }
        assert_eq!(cache_key(&cfg).len(), 16);
    }

    #[test]
    fn result_roundtrip_and_miss_semantics() {
        let (store, dir) = tmp_store("result");
        let cfg = presets::smoke();
        assert!(store.load_result(&cfg).is_none());
        let log = TrainLog {
            label: "raw".into(),
            records: vec![],
            measured_avg_power: vec![1.0, 2.0],
            pbar: 500.0,
            final_accuracy: 0.75,
            total_secs: 3.5,
        };
        store.save_result(&cfg, "smoke", &log).unwrap();
        let back = store.load_result(&cfg).unwrap();
        assert_eq!(back.final_accuracy, 0.75);
        assert_eq!(back.measured_avg_power, vec![1.0, 2.0]);
        // A different config misses even with the store populated.
        let other = RunConfig { seed: cfg.seed + 9, ..cfg.clone() };
        assert!(store.load_result(&other).is_none());
        // Listing shows one complete entry.
        let listing = store.list();
        assert_eq!(listing.len(), 1);
        assert_eq!(listing[0].status, RunStatus::Complete);
        assert_eq!(listing[0].label, "smoke");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_roundtrip_and_result_supersedes_it() {
        let (store, dir) = tmp_store("snap");
        let cfg = presets::smoke();
        let snap = TrainerSnapshot {
            config_hash: config_hash(&cfg),
            next_round: 5,
            params: vec![1.0; 4],
            optim_m: vec![0.0; 4],
            optim_v: vec![0.0; 4],
            optim_t: 5,
            link: vec![9, 9],
            records: vec![],
            final_accuracy: 0.25,
        };
        store.save_snapshot(&cfg, "smoke", &snap).unwrap();
        let back = store.load_snapshot(&cfg).unwrap();
        assert_eq!(back.next_round, 5);
        assert_eq!(store.list()[0].status, RunStatus::Partial);
        assert_eq!(store.list()[0].snapshot_round, 5);
        // Wrong-config snapshots are refused even if the file were there.
        let other = RunConfig { seed: cfg.seed + 1, ..cfg.clone() };
        assert!(store.load_snapshot(&other).is_none());
        // Completing the run drops the stale snapshot.
        let log = TrainLog {
            label: "raw".into(),
            records: vec![],
            measured_avg_power: vec![],
            pbar: 500.0,
            final_accuracy: 0.5,
            total_secs: 1.0,
        };
        store.save_result(&cfg, "smoke", &log).unwrap();
        assert!(store.load_snapshot(&cfg).is_none());
        assert_eq!(store.list()[0].status, RunStatus::Complete);
        let _ = fs::remove_dir_all(&dir);
    }

    fn snap_at(cfg: &RunConfig, round: usize) -> TrainerSnapshot {
        TrainerSnapshot {
            config_hash: config_hash(cfg),
            next_round: round,
            params: vec![round as f32; 4],
            optim_m: vec![0.0; 4],
            optim_v: vec![0.0; 4],
            optim_t: round as u64,
            link: vec![7; 3],
            records: vec![],
            final_accuracy: 0.1 * round as f64,
        }
    }

    #[test]
    fn retention_keeps_last_n_rounds_and_gc_prunes() {
        let (store, dir) = tmp_store("retain");
        let cfg = presets::smoke();
        for round in 1..=5 {
            store
                .save_snapshot_retained(&cfg, "smoke", &snap_at(&cfg, round), 3)
                .unwrap();
        }
        let entry = dir.join(cache_key(&cfg));
        let rounds: Vec<usize> = history_snapshots(&entry).iter().map(|&(r, _)| r).collect();
        assert_eq!(rounds, vec![5, 4, 3], "newest three rounds retained");
        assert_eq!(store.load_best_snapshot(&cfg).unwrap().next_round, 5);

        // gc with a tighter policy prunes further; the latest blob stays.
        let report = store.gc(1).unwrap();
        assert_eq!(report.entries, 1);
        assert!(report.files_removed >= 2, "{report:?}");
        assert!(report.bytes_reclaimed > 0);
        let rounds: Vec<usize> = history_snapshots(&entry).iter().map(|&(r, _)| r).collect();
        assert_eq!(rounds, vec![5]);
        assert_eq!(store.load_snapshot(&cfg).unwrap().next_round, 5);

        // Completing the run lets gc drop every snapshot blob.
        let log = TrainLog {
            label: "raw".into(),
            records: vec![],
            measured_avg_power: vec![],
            pbar: 500.0,
            final_accuracy: 0.5,
            total_secs: 1.0,
        };
        store.save_result(&cfg, "smoke", &log).unwrap();
        store.gc(3).unwrap();
        assert!(history_snapshots(&entry).is_empty());
        assert!(!entry.join("snapshot.bin").exists());
        assert!(store.load_result(&cfg).is_some(), "gc must never touch results");
        let _ = fs::remove_dir_all(&dir);
    }

    /// A bit-flipped result blob must be quarantined and read as a miss —
    /// the checksum catches it, the campaign recomputes, nothing aborts.
    #[test]
    fn corrupt_result_is_quarantined_not_fatal() {
        let (store, dir) = tmp_store("corrupt_result");
        let cfg = presets::smoke();
        let log = TrainLog {
            label: "raw".into(),
            records: vec![],
            measured_avg_power: vec![1.0],
            pbar: 500.0,
            final_accuracy: 0.75,
            total_secs: 3.5,
        };
        store.save_result(&cfg, "smoke", &log).unwrap();
        let path = dir.join(cache_key(&cfg)).join("result.bin");
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        fs::write(&path, &bytes).unwrap();

        assert!(store.load_result(&cfg).is_none(), "corrupt blob must read as a miss");
        assert!(!path.exists(), "corrupt blob must leave the load path");
        assert!(
            path.with_extension("bin.corrupt").exists(),
            "corrupt blob must be kept for forensics"
        );
        // The entry is writable again: a recompute lands cleanly.
        store.save_result(&cfg, "smoke", &log).unwrap();
        assert!(store.load_result(&cfg).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    /// A bit-flipped latest snapshot falls back to the newest retained
    /// history round instead of restarting the run from scratch.
    #[test]
    fn corrupt_snapshot_falls_back_to_history() {
        let (store, dir) = tmp_store("corrupt_snap");
        let cfg = presets::smoke();
        for round in 1..=4 {
            store
                .save_snapshot_retained(&cfg, "smoke", &snap_at(&cfg, round), 3)
                .unwrap();
        }
        let entry = dir.join(cache_key(&cfg));
        // Corrupt both copies of round 4 (snapshot.bin and its history
        // twin) so the fall-back has to reach round 3.
        for name in ["snapshot.bin".to_string(), history_name(4)] {
            let path = entry.join(name);
            let mut bytes = fs::read(&path).unwrap();
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0x04;
            fs::write(&path, &bytes).unwrap();
        }
        let best = store.load_best_snapshot(&cfg).expect("history fall-back");
        assert_eq!(best.next_round, 3);
        assert!(entry.join("snapshot.bin.corrupt").exists());
        // And with *every* blob corrupt, the answer is an honest None.
        let path = entry.join(history_name(3));
        let mut bytes = fs::read(&path).unwrap();
        bytes[10] ^= 0x80;
        fs::write(&path, &bytes).unwrap();
        let path = entry.join(history_name(2));
        let mut bytes = fs::read(&path).unwrap();
        bytes[10] ^= 0x80;
        fs::write(&path, &bytes).unwrap();
        assert!(store.load_best_snapshot(&cfg).is_none());
        let _ = fs::remove_dir_all(&dir);
    }
}
