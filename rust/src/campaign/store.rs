//! The content-addressed run store: results and snapshots keyed by a
//! stable hash of the canonicalized `RunConfig`.
//!
//! # Cache-key canonicalization
//!
//! [`canonical_config`] renders *every* `RunConfig` field as one
//! `key=value` line in a fixed order, using each enum's canonical string
//! form (`Scheme::name`, `FadingDist::describe`, …) and `f64` `Display`
//! (shortest round-trip form, so `500.0` and `500.00` collide as they
//! should). [`config_hash`] is FNV-1a 64 over those bytes and
//! [`cache_key`] its 16-hex-digit rendering — the store directory name.
//!
//! Two deliberate properties:
//!
//! * **Never a false hit.** Fields a scheme happens to ignore (e.g. the
//!   `[topology]` table under an error-free run) are still hashed, so the
//!   key is conservatively fine-grained: a config change can only ever
//!   *miss* the cache, never collide into the wrong entry.
//! * **Labels are not identity.** The experiment label is display metadata
//!   recorded in the manifest; renaming a run in a figure spec still hits
//!   the cache for the identical config.
//!
//! # Layout
//!
//! ```text
//! <store_dir>/<cache_key>/manifest.toml   # human-readable index entry
//! <store_dir>/<cache_key>/snapshot.bin    # latest TrainerSnapshot (partial runs)
//! <store_dir>/<cache_key>/result.bin      # finished TrainLog (complete runs)
//! ```
//!
//! All writes go through a temp-file + rename, so a crash mid-write leaves
//! the previous blob intact — the whole point of the subsystem.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::config::{Backend, DatasetSpec, RunConfig};
use crate::coordinator::TrainLog;

use super::manifest::{RunManifest, RunStatus};
use super::snapshot::{decode_log, encode_log, fnv1a64, TrainerSnapshot, SNAPSHOT_VERSION};

/// Render every config field in fixed order with canonical value forms.
/// The exhaustive destructuring (no `..`) is load-bearing: adding a field
/// to `RunConfig` without deciding its canonical rendering fails to
/// compile here, which is what keeps "never a false cache hit" true over
/// time.
pub fn canonical_config(cfg: &RunConfig) -> String {
    let RunConfig {
        scheme,
        devices,
        local_samples,
        channel_uses,
        sparsity,
        pbar,
        noise_var,
        iterations,
        power,
        lr,
        noniid,
        seed,
        mean_removal_rounds,
        qsgd_levels,
        backend,
        dataset,
        eval_every,
        amp_iters,
        amp_tol,
        amp_threshold_mult,
        fading,
        csi_threshold,
        participation,
        deadline_secs,
        latency_mean_secs,
        fading_rho,
        topology,
    } = cfg;
    let crate::config::TopologyConfig {
        family,
        degree,
        p,
        mixing,
        seed: topology_seed,
    } = topology;
    let backend = match backend {
        Backend::Rust => "rust",
        Backend::Pjrt => "pjrt",
    };
    let dataset = match dataset {
        DatasetSpec::Synthetic { train, test } => format!("synthetic:{train}:{test}"),
        DatasetSpec::MnistIdx { dir } => format!("mnist:{dir}"),
    };
    format!(
        "scheme={}\ndevices={devices}\nlocal_samples={local_samples}\nchannel_uses={channel_uses}\nsparsity={sparsity}\npbar={pbar}\nnoise_var={noise_var}\niterations={iterations}\npower={}\nlr={lr}\nnoniid={noniid}\nseed={seed}\nmean_removal_rounds={mean_removal_rounds}\nqsgd_levels={qsgd_levels}\nbackend={backend}\ndataset={dataset}\neval_every={eval_every}\namp_iters={amp_iters}\namp_tol={amp_tol}\namp_threshold_mult={amp_threshold_mult}\nfading={}\ncsi_threshold={csi_threshold}\nparticipation={}\ndeadline_secs={deadline_secs}\nlatency_mean_secs={latency_mean_secs}\nfading_rho={fading_rho}\ntopology_family={}\ntopology_degree={degree}\ntopology_p={p}\ntopology_mixing={}\ntopology_seed={topology_seed}\n",
        scheme.name(),
        power.name(),
        fading.describe(),
        participation.describe(),
        family.name(),
        mixing.name(),
    )
}

/// FNV-1a 64 over the canonical rendering — the run's stable identity.
pub fn config_hash(cfg: &RunConfig) -> u64 {
    fnv1a64(canonical_config(cfg).as_bytes())
}

/// The store address of a config: `config_hash` as 16 hex digits.
pub fn cache_key(cfg: &RunConfig) -> String {
    format!("{:016x}", config_hash(cfg))
}

/// Crash-safe write: temp file in the same directory, fsync'd before the
/// rename — without the sync, journaling filesystems may commit the
/// rename ahead of the data blocks and a power cut would leave a torn
/// blob where the previous good one used to be. The temp name is unique
/// per process *and* per write, so two campaigns sharing a store (or two
/// parallel workers hitting one entry) never interleave into the same
/// temp file; last rename wins with a complete blob either way.
fn write_atomic(path: &Path, bytes: &[u8]) -> io::Result<()> {
    use std::io::Write as _;
    use std::sync::atomic::{AtomicU64, Ordering};
    static WRITE_SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = WRITE_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = path.with_extension(format!("tmp.{}.{seq}", std::process::id()));
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)
}

/// A directory of content-addressed run entries.
pub struct RunStore {
    root: PathBuf,
}

impl RunStore {
    /// Open (creating if needed) the store rooted at `dir`.
    pub fn open(dir: &str) -> io::Result<RunStore> {
        let root = PathBuf::from(dir);
        fs::create_dir_all(&root)?;
        Ok(RunStore { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn entry_dir(&self, cfg: &RunConfig) -> PathBuf {
        self.root.join(cache_key(cfg))
    }

    /// The finished result for `cfg`, if cached. Any decode problem
    /// (truncation, version skew) reads as a miss, never an error — the
    /// run simply re-executes.
    pub fn load_result(&self, cfg: &RunConfig) -> Option<TrainLog> {
        let bytes = fs::read(self.entry_dir(cfg).join("result.bin")).ok()?;
        decode_log(&bytes).ok()
    }

    /// The latest snapshot for `cfg`, if one exists and belongs to this
    /// exact config (the embedded hash is checked on top of the address).
    pub fn load_snapshot(&self, cfg: &RunConfig) -> Option<TrainerSnapshot> {
        let bytes = fs::read(self.entry_dir(cfg).join("snapshot.bin")).ok()?;
        let snap = TrainerSnapshot::decode(&bytes).ok()?;
        if snap.config_hash != config_hash(cfg) {
            return None;
        }
        Some(snap)
    }

    /// Persist a mid-run snapshot and mark the entry partial.
    pub fn save_snapshot(
        &self,
        cfg: &RunConfig,
        label: &str,
        snap: &TrainerSnapshot,
    ) -> io::Result<()> {
        let dir = self.entry_dir(cfg);
        fs::create_dir_all(&dir)?;
        write_atomic(&dir.join("snapshot.bin"), &snap.encode())?;
        let manifest = RunManifest {
            key: cache_key(cfg),
            label: label.to_string(),
            summary: cfg.summary(),
            status: RunStatus::Partial,
            snapshot_round: snap.next_round,
            iterations: cfg.iterations,
            version: SNAPSHOT_VERSION,
        };
        write_atomic(&dir.join("manifest.toml"), manifest.to_toml().as_bytes())
    }

    /// Persist a finished run's log and mark the entry complete. The
    /// now-stale snapshot blob is dropped.
    pub fn save_result(&self, cfg: &RunConfig, label: &str, log: &TrainLog) -> io::Result<()> {
        let dir = self.entry_dir(cfg);
        fs::create_dir_all(&dir)?;
        write_atomic(&dir.join("result.bin"), &encode_log(log))?;
        let manifest = RunManifest {
            key: cache_key(cfg),
            label: label.to_string(),
            summary: cfg.summary(),
            status: RunStatus::Complete,
            snapshot_round: cfg.iterations,
            iterations: cfg.iterations,
            version: SNAPSHOT_VERSION,
        };
        write_atomic(&dir.join("manifest.toml"), manifest.to_toml().as_bytes())?;
        let _ = fs::remove_file(dir.join("snapshot.bin"));
        Ok(())
    }

    /// All readable manifests, sorted by key (deterministic listing for
    /// `repro status`). Unreadable entries are skipped, not fatal.
    pub fn list(&self) -> Vec<RunManifest> {
        let mut out = Vec::new();
        let Ok(entries) = fs::read_dir(&self.root) else {
            return out;
        };
        for entry in entries.flatten() {
            let manifest_path = entry.path().join("manifest.toml");
            if let Ok(m) = RunManifest::read(&manifest_path) {
                out.push(m);
            }
        }
        out.sort_by(|a, b| a.key.cmp(&b.key));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Scheme};

    fn tmp_store(name: &str) -> (RunStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!("ota_store_{name}"));
        let _ = fs::remove_dir_all(&dir);
        let store = RunStore::open(dir.to_str().unwrap()).unwrap();
        (store, dir)
    }

    #[test]
    fn hash_is_stable_and_field_sensitive() {
        let cfg = presets::smoke();
        assert_eq!(config_hash(&cfg), config_hash(&cfg.clone()));
        // Every semantically distinct knob must move the key.
        let variants = [
            RunConfig { seed: cfg.seed + 1, ..cfg.clone() },
            RunConfig { scheme: Scheme::DDsgd, ..cfg.clone() },
            RunConfig { iterations: cfg.iterations + 1, ..cfg.clone() },
            RunConfig { pbar: cfg.pbar * 2.0, ..cfg.clone() },
            RunConfig { fading_rho: 0.5, ..cfg.clone() },
            RunConfig { eval_every: cfg.eval_every + 1, ..cfg.clone() },
        ];
        let base = config_hash(&cfg);
        for v in &variants {
            assert_ne!(config_hash(v), base, "{}", canonical_config(v));
        }
        assert_eq!(cache_key(&cfg).len(), 16);
    }

    #[test]
    fn result_roundtrip_and_miss_semantics() {
        let (store, dir) = tmp_store("result");
        let cfg = presets::smoke();
        assert!(store.load_result(&cfg).is_none());
        let log = TrainLog {
            label: "raw".into(),
            records: vec![],
            measured_avg_power: vec![1.0, 2.0],
            pbar: 500.0,
            final_accuracy: 0.75,
            total_secs: 3.5,
        };
        store.save_result(&cfg, "smoke", &log).unwrap();
        let back = store.load_result(&cfg).unwrap();
        assert_eq!(back.final_accuracy, 0.75);
        assert_eq!(back.measured_avg_power, vec![1.0, 2.0]);
        // A different config misses even with the store populated.
        let other = RunConfig { seed: cfg.seed + 9, ..cfg.clone() };
        assert!(store.load_result(&other).is_none());
        // Listing shows one complete entry.
        let listing = store.list();
        assert_eq!(listing.len(), 1);
        assert_eq!(listing[0].status, RunStatus::Complete);
        assert_eq!(listing[0].label, "smoke");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_roundtrip_and_result_supersedes_it() {
        let (store, dir) = tmp_store("snap");
        let cfg = presets::smoke();
        let snap = TrainerSnapshot {
            config_hash: config_hash(&cfg),
            next_round: 5,
            params: vec![1.0; 4],
            optim_m: vec![0.0; 4],
            optim_v: vec![0.0; 4],
            optim_t: 5,
            link: vec![9, 9],
            records: vec![],
            final_accuracy: 0.25,
        };
        store.save_snapshot(&cfg, "smoke", &snap).unwrap();
        let back = store.load_snapshot(&cfg).unwrap();
        assert_eq!(back.next_round, 5);
        assert_eq!(store.list()[0].status, RunStatus::Partial);
        assert_eq!(store.list()[0].snapshot_round, 5);
        // Wrong-config snapshots are refused even if the file were there.
        let other = RunConfig { seed: cfg.seed + 1, ..cfg.clone() };
        assert!(store.load_snapshot(&other).is_none());
        // Completing the run drops the stale snapshot.
        let log = TrainLog {
            label: "raw".into(),
            records: vec![],
            measured_avg_power: vec![],
            pbar: 500.0,
            final_accuracy: 0.5,
            total_secs: 1.0,
        };
        store.save_result(&cfg, "smoke", &log).unwrap();
        assert!(store.load_snapshot(&cfg).is_none());
        assert_eq!(store.list()[0].status, RunStatus::Complete);
        let _ = fs::remove_dir_all(&dir);
    }
}
