//! PS-side optimizers operating on the flat parameter vector.
//!
//! The paper's experiments use ADAM (§VI, [46]); plain SGD with the paper's
//! η_t schedule is provided for the convergence-analysis experiments (§V
//! assumes constant-η SGD).

/// Optimizer trait: consume a (possibly reconstructed/noisy) gradient
/// estimate and update the parameters in place.
pub trait Optimizer: Send {
    fn step(&mut self, params: &mut [f32], grad: &[f32]);
    fn reset(&mut self);
    fn name(&self) -> &'static str;

    /// Mutable state export for checkpointing: `(first moment, second
    /// moment, step count)`. Stateless optimizers return empty vectors and
    /// 0 — restoring those is a no-op by construction.
    fn export_state(&self) -> (Vec<f32>, Vec<f32>, u64) {
        (Vec::new(), Vec::new(), 0)
    }

    /// Restore state captured by [`Optimizer::export_state`] into a
    /// freshly-built optimizer of the same kind and dimension.
    fn import_state(&mut self, _m: &[f32], _v: &[f32], _t: u64) {}
}

/// ADAM (Kingma & Ba) with bias correction.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(dim: usize, lr: f32) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t: 0,
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let lr_t = self.lr * b2t.sqrt() / b1t;
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            params[i] -= lr_t * self.m[i] / (self.v[i].sqrt() + self.eps);
        }
    }

    fn reset(&mut self) {
        self.m.fill(0.0);
        self.v.fill(0.0);
        self.t = 0;
    }

    fn name(&self) -> &'static str {
        "adam"
    }

    fn export_state(&self) -> (Vec<f32>, Vec<f32>, u64) {
        (self.m.clone(), self.v.clone(), self.t)
    }

    fn import_state(&mut self, m: &[f32], v: &[f32], t: u64) {
        assert_eq!(m.len(), self.m.len(), "Adam restore dimension mismatch");
        assert_eq!(v.len(), self.v.len(), "Adam restore dimension mismatch");
        self.m.copy_from_slice(m);
        self.v.copy_from_slice(v);
        self.t = t;
    }
}

/// Plain SGD with constant learning rate (the §V analysis setting).
#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f32,
}

impl Sgd {
    pub fn new(lr: f32) -> Sgd {
        Sgd { lr }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        for (p, g) in params.iter_mut().zip(grad) {
            *p -= self.lr * g;
        }
    }

    fn reset(&mut self) {}

    fn name(&self) -> &'static str {
        "sgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimize f(x) = Σ (x_i − i)² — both optimizers should converge.
    fn quad_grad(x: &[f32]) -> Vec<f32> {
        x.iter()
            .enumerate()
            .map(|(i, &v)| 2.0 * (v - i as f32))
            .collect()
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let mut x = vec![10.0f32; 5];
        let mut opt = Adam::new(5, 0.1);
        for _ in 0..2000 {
            let g = quad_grad(&x);
            opt.step(&mut x, &g);
        }
        for (i, &v) in x.iter().enumerate() {
            assert!((v - i as f32).abs() < 0.05, "x[{i}]={v}");
        }
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut x = vec![-3.0f32; 4];
        let mut opt = Sgd::new(0.1);
        for _ in 0..500 {
            let g = quad_grad(&x);
            opt.step(&mut x, &g);
        }
        for (i, &v) in x.iter().enumerate() {
            assert!((v - i as f32).abs() < 1e-3);
        }
    }

    #[test]
    fn adam_reset_clears_state() {
        let mut opt = Adam::new(3, 0.1);
        let mut x = vec![1.0f32; 3];
        opt.step(&mut x, &[1.0, 1.0, 1.0]);
        opt.reset();
        assert_eq!(opt.t, 0);
        assert!(opt.m.iter().all(|&v| v == 0.0));
        assert!(opt.v.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn adam_state_roundtrip_continues_identically() {
        let mut a = Adam::new(4, 0.05);
        let mut xa = vec![1.0f32; 4];
        for _ in 0..5 {
            let g = quad_grad(&xa);
            a.step(&mut xa, &g);
        }
        let (m, v, t) = a.export_state();
        assert_eq!(t, 5);
        let mut b = Adam::new(4, 0.05);
        b.import_state(&m, &v, t);
        let mut xb = xa.clone();
        for _ in 0..10 {
            let ga = quad_grad(&xa);
            a.step(&mut xa, &ga);
            let gb = quad_grad(&xb);
            b.step(&mut xb, &gb);
            assert_eq!(xa, xb, "restored Adam must continue bit-identically");
        }
    }

    #[test]
    fn sgd_state_is_empty() {
        let opt = Sgd::new(0.1);
        let (m, v, t) = opt.export_state();
        assert!(m.is_empty() && v.is_empty() && t == 0);
    }

    #[test]
    fn adam_first_step_size_is_lr() {
        // With bias correction the first step ≈ lr · sign(g).
        let mut opt = Adam::new(1, 0.01);
        let mut x = vec![0.0f32];
        opt.step(&mut x, &[3.0]);
        assert!((x[0] + 0.01).abs() < 1e-4, "x={}", x[0]);
    }
}
