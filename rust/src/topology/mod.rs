//! Device-to-device topology: who can hear whom, and with what mixing
//! weights — the graph layer under the decentralized (no parameter server)
//! training path.
//!
//! # The graph / mixing / diagnostics contract
//!
//! The subsystem splits into two pieces, mirroring the
//! [`crate::coordinator::link`] contract of "everything between gradients
//! and ĝ lives behind one interface":
//!
//! 1. **[`Graph`]** — the communication topology. Built deterministically
//!    from the `[topology]` config (family, degree/p, seed) by
//!    [`Graph::build`]; every family (fully-connected, ring, 2-D torus,
//!    Erdős–Rényi, star) comes out *connected*, undirected and
//!    self-loop-free, with any randomness drawn through counter-based RNG
//!    cells so the adjacency is a pure function of the config. The graph
//!    answers the per-round questions the D2D link asks: the sorted
//!    [closed neighborhood](Graph::closed_neighborhood) receiver *i*
//!    decodes each round, and the canonical [pair id](Graph::pair_id) that
//!    keys the reciprocal per-edge gain process (h_ij = h_ji).
//! 2. **[`MixingMatrix`]** — the consensus weights over the graph.
//!    Metropolis–Hastings (per-edge degrees) or max-degree (one global
//!    constant) construction; both are **symmetric** and
//!    **doubly-stochastic** with non-negative entries on any connected
//!    graph, which is exactly what the decentralized update
//!    θ_i ← θ_i + Σ_j W_ij (θ_j − θ_i) needs to preserve the replica
//!    average and contract disagreement. The contraction rate is surfaced
//!    as [`MixingMatrix::spectral_gap`] (1 − ρ(W − 11ᵀ/M)), so experiment
//!    logs can relate a topology's connectivity to its convergence.
//!
//! # Invariants (property-tested)
//!
//! `rust/tests/topology_properties.rs` pins, for random seeds, sizes and
//! families:
//!
//! * connectivity of every built graph;
//! * exact symmetry of W and row sums within 1e-12 of 1;
//! * non-negative weights and a strictly positive spectral gap;
//! * the fully-connected degeneracy: Metropolis weights on the complete
//!   graph are the uniform 1/M matrix, which collapses D2D consensus to
//!   the star A-DSGD average (`rust/tests/golden_schemes.rs` pins the full
//!   training trajectory bit-for-bit).
//!
//! The consumer of all of this is
//! [`crate::coordinator::link::D2dAnalogLink`], which plugs the graph and
//! weights into the scheme-agnostic trainer loop as one more
//! [`crate::coordinator::link::LinkScheme`].

pub mod graph;
pub mod mixing;

pub use graph::Graph;
pub use mixing::MixingMatrix;
