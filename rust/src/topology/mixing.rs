//! Mixing-weight construction over a [`Graph`] and its consensus
//! diagnostics.
//!
//! The decentralized update θ_i ← θ_i + Σ_j W_ij (θ_j − θ_i) needs a
//! symmetric, doubly-stochastic W supported on the graph for the replica
//! average to be preserved and for consensus to contract at rate given by
//! the spectral gap 1 − ρ(W − 11ᵀ/M). Both rules here guarantee those
//! invariants on any connected graph (asserted at construction, and
//! property-tested over random families in
//! `rust/tests/topology_properties.rs`).

use crate::config::MixingRule;

use super::graph::Graph;

/// A dense symmetric doubly-stochastic mixing matrix over M devices.
#[derive(Clone, Debug)]
pub struct MixingMatrix {
    m: usize,
    /// Row-major M × M weights.
    w: Vec<f64>,
}

impl MixingMatrix {
    /// Build the configured rule's weights for `graph`.
    pub fn build(graph: &Graph, rule: MixingRule) -> MixingMatrix {
        let w = match rule {
            MixingRule::Metropolis => Self::metropolis(graph),
            MixingRule::MaxDegree => Self::max_degree(graph),
        };
        debug_assert!(w.max_symmetry_error() == 0.0);
        debug_assert!(w.max_row_sum_error() < 1e-12);
        w
    }

    /// Metropolis–Hastings: W_ij = 1/(1 + max(deg_i, deg_j)) on edges; the
    /// diagonal absorbs the remainder. Symmetric by construction (the
    /// weight depends only on the unordered pair) and rows sum to 1 exactly
    /// up to f64 rounding. On the complete graph every weight is 1/M — the
    /// uniform averaging matrix the degeneracy golden relies on.
    pub fn metropolis(graph: &Graph) -> MixingMatrix {
        let m = graph.devices();
        let mut w = vec![0.0f64; m * m];
        for i in 0..m {
            let mut off_diag = 0.0f64;
            for &j in graph.neighbors(i) {
                let wij = 1.0 / (1.0 + graph.degree(i).max(graph.degree(j)) as f64);
                w[i * m + j] = wij;
                off_diag += wij;
            }
            w[i * m + i] = 1.0 - off_diag;
        }
        MixingMatrix { m, w }
    }

    /// Max-degree weights: W_ij = 1/(1 + Δ) on edges with Δ the global
    /// maximum degree. One global constant instead of per-edge degrees;
    /// mixes slower than Metropolis on irregular graphs.
    pub fn max_degree(graph: &Graph) -> MixingMatrix {
        let m = graph.devices();
        let wij = 1.0 / (1.0 + graph.max_degree() as f64);
        let mut w = vec![0.0f64; m * m];
        for i in 0..m {
            for &j in graph.neighbors(i) {
                w[i * m + j] = wij;
            }
            w[i * m + i] = 1.0 - graph.degree(i) as f64 * wij;
        }
        MixingMatrix { m, w }
    }

    pub fn devices(&self) -> usize {
        self.m
    }

    pub fn weight(&self, i: usize, j: usize) -> f64 {
        self.w[i * self.m + j]
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.w[i * self.m..(i + 1) * self.m]
    }

    /// max |W_ij − W_ji| (0 for both construction rules).
    pub fn max_symmetry_error(&self) -> f64 {
        let mut err = 0.0f64;
        for i in 0..self.m {
            for j in (i + 1)..self.m {
                err = err.max((self.weight(i, j) - self.weight(j, i)).abs());
            }
        }
        err
    }

    /// max_i |Σ_j W_ij − 1| — doubly stochastic together with symmetry.
    pub fn max_row_sum_error(&self) -> f64 {
        (0..self.m)
            .map(|i| (self.row(i).iter().sum::<f64>() - 1.0).abs())
            .fold(0.0, f64::max)
    }

    /// Smallest entry (diagonal included). Non-negative for both rules on
    /// any graph, which makes W a lazy random walk and bounds ρ < 1 on
    /// connected graphs.
    pub fn min_weight(&self) -> f64 {
        self.w.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Spectral gap 1 − ρ(W − 11ᵀ/M): the consensus contraction rate per
    /// mixing step. Estimated by deterministic power iteration on the
    /// 1⊥-restricted operator (W is symmetric, so the dominant deflated
    /// eigenvalue magnitude is ρ).
    pub fn spectral_gap(&self) -> f64 {
        let m = self.m;
        if m == 1 {
            return 1.0;
        }
        // Fixed, seed-free start vector with energy on every deflated mode.
        let mut x: Vec<f64> = (0..m)
            .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 } + 0.1 * (i as f64 + 1.0))
            .collect();
        deflate(&mut x);
        normalize(&mut x);
        let mut rho = 0.0f64;
        for _ in 0..400 {
            let mut y = vec![0.0f64; m];
            for i in 0..m {
                let row = self.row(i);
                y[i] = row.iter().zip(&x).map(|(w, v)| w * v).sum();
            }
            deflate(&mut y);
            let norm = y.iter().map(|v| v * v).sum::<f64>().sqrt();
            if norm < 1e-300 {
                // W restricted to 1⊥ is (numerically) zero — exact
                // one-step consensus, e.g. the complete graph.
                return 1.0;
            }
            rho = norm;
            x = y;
            normalize(&mut x);
        }
        (1.0 - rho).max(0.0)
    }
}

fn deflate(x: &mut [f64]) {
    let mean = x.iter().sum::<f64>() / x.len() as f64;
    for v in x.iter_mut() {
        *v -= mean;
    }
}

fn normalize(x: &mut [f64]) {
    let norm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    if norm > 0.0 {
        for v in x.iter_mut() {
            *v /= norm;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphFamily, TopologyConfig};

    fn graph(family: GraphFamily, m: usize) -> Graph {
        let topo = TopologyConfig {
            family,
            seed: 5,
            ..TopologyConfig::default()
        };
        Graph::build(&topo, m, 1)
    }

    #[test]
    fn metropolis_on_complete_graph_is_uniform() {
        let g = graph(GraphFamily::Full, 8);
        let w = MixingMatrix::metropolis(&g);
        for i in 0..8 {
            for j in 0..8 {
                assert!((w.weight(i, j) - 1.0 / 8.0).abs() < 1e-15, "W[{i}][{j}]");
            }
        }
        // Exact one-step consensus.
        assert!((w.spectral_gap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn invariants_hold_on_every_family() {
        for family in [
            GraphFamily::Full,
            GraphFamily::Ring,
            GraphFamily::Torus,
            GraphFamily::ErdosRenyi,
            GraphFamily::Star,
        ] {
            for rule in [MixingRule::Metropolis, MixingRule::MaxDegree] {
                let g = graph(family, 12);
                let w = MixingMatrix::build(&g, rule);
                assert_eq!(w.max_symmetry_error(), 0.0, "{family:?}/{rule:?}");
                assert!(w.max_row_sum_error() < 1e-12, "{family:?}/{rule:?}");
                assert!(w.min_weight() >= 0.0, "{family:?}/{rule:?}");
                let gap = w.spectral_gap();
                assert!(
                    gap > 0.0 && gap <= 1.0 + 1e-12,
                    "{family:?}/{rule:?}: gap {gap}"
                );
            }
        }
    }

    #[test]
    fn ring_gap_matches_closed_form() {
        // Metropolis on a cycle: W = I/3 on the diagonal, 1/3 per edge —
        // eigenvalues (1 + 2cos(2πk/M))/3; ρ = (1 + 2cos(2π/M))/3.
        let m = 10;
        let g = graph(GraphFamily::Ring, m);
        let w = MixingMatrix::metropolis(&g);
        let rho = (1.0 + 2.0 * (2.0 * std::f64::consts::PI / m as f64).cos()) / 3.0;
        assert!(
            (w.spectral_gap() - (1.0 - rho)).abs() < 1e-6,
            "gap {} vs closed-form {}",
            w.spectral_gap(),
            1.0 - rho
        );
    }

    #[test]
    fn denser_graphs_mix_faster() {
        let ring = MixingMatrix::metropolis(&graph(GraphFamily::Ring, 16));
        let torus = MixingMatrix::metropolis(&graph(GraphFamily::Torus, 16));
        let full = MixingMatrix::metropolis(&graph(GraphFamily::Full, 16));
        assert!(ring.spectral_gap() < torus.spectral_gap());
        assert!(torus.spectral_gap() < full.spectral_gap());
    }
}
