//! Deterministic construction of the D2D communication graphs.
//!
//! Every family is built as a pure function of `(TopologyConfig, M, seed)`,
//! with any randomness (Erdős–Rényi edges) drawn through
//! [`crate::util::rng::counter_rng`] keyed by the canonical unordered pair
//! id — the graph does not depend on construction order, and the same seed
//! always yields the same adjacency.

use crate::config::{GraphFamily, TopologyConfig};
use crate::util::rng::counter_rng;

/// An undirected, connected device-to-device communication graph.
#[derive(Clone, Debug)]
pub struct Graph {
    family: GraphFamily,
    /// Sorted neighbor lists, no self loops.
    neighbors: Vec<Vec<usize>>,
}

impl Graph {
    /// Build the configured family over `m` devices. `fallback_seed` is
    /// used when the topology config leaves its seed at 0 (derive from the
    /// run seed).
    pub fn build(topo: &TopologyConfig, m: usize, fallback_seed: u64) -> Graph {
        assert!(m >= 2, "a D2D graph needs at least two devices");
        let seed = if topo.seed != 0 {
            topo.seed
        } else {
            fallback_seed
        };
        let neighbors = match topo.family {
            GraphFamily::Full => full(m),
            GraphFamily::Ring => ring(m, topo.degree),
            GraphFamily::Torus => torus(m),
            GraphFamily::ErdosRenyi => erdos_renyi(m, topo.p, seed),
            GraphFamily::Star => star(m),
        };
        let g = Graph {
            family: topo.family,
            neighbors,
        };
        debug_assert!(g.is_connected(), "{:?} graph must come out connected", topo.family);
        g
    }

    pub fn family(&self) -> GraphFamily {
        self.family
    }

    /// Number of devices M.
    pub fn devices(&self) -> usize {
        self.neighbors.len()
    }

    /// Sorted open neighborhood of device `i` (excludes `i`).
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.neighbors[i]
    }

    /// Sorted closed neighborhood of device `i` (includes `i`): the set
    /// whose superposed frames receiver `i` decodes each round.
    pub fn closed_neighborhood(&self, i: usize) -> Vec<usize> {
        let mut hood = Vec::with_capacity(self.neighbors[i].len() + 1);
        let mut inserted = false;
        for &j in &self.neighbors[i] {
            if !inserted && j > i {
                hood.push(i);
                inserted = true;
            }
            hood.push(j);
        }
        if !inserted {
            hood.push(i);
        }
        hood
    }

    pub fn degree(&self, i: usize) -> usize {
        self.neighbors[i].len()
    }

    pub fn max_degree(&self) -> usize {
        self.neighbors.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Number of undirected edges.
    pub fn edge_count(&self) -> usize {
        self.neighbors.iter().map(Vec::len).sum::<usize>() / 2
    }

    /// Canonical id of the unordered pair {i, j}: both directions of an
    /// edge map to the same id, which keys the reciprocal per-edge gain
    /// process (h_ij = h_ji).
    pub fn pair_id(&self, i: usize, j: usize) -> u64 {
        let m = self.devices() as u64;
        let (lo, hi) = if i <= j { (i as u64, j as u64) } else { (j as u64, i as u64) };
        lo * m + hi
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        let m = self.devices();
        let mut seen = vec![false; m];
        let mut queue = vec![0usize];
        seen[0] = true;
        let mut reached = 1usize;
        while let Some(i) = queue.pop() {
            for &j in &self.neighbors[i] {
                if !seen[j] {
                    seen[j] = true;
                    reached += 1;
                    queue.push(j);
                }
            }
        }
        reached == m
    }
}

/// Turn an edge set into sorted, deduplicated neighbor lists.
fn to_neighbors(m: usize, edges: &[(usize, usize)]) -> Vec<Vec<usize>> {
    let mut nb = vec![Vec::new(); m];
    for &(a, b) in edges {
        if a != b {
            nb[a].push(b);
            nb[b].push(a);
        }
    }
    for list in nb.iter_mut() {
        list.sort_unstable();
        list.dedup();
    }
    nb
}

fn full(m: usize) -> Vec<Vec<usize>> {
    (0..m)
        .map(|i| (0..m).filter(|&j| j != i).collect())
        .collect()
}

/// Cycle with `degree` neighbors on each side (degree 1 = plain ring).
/// Offsets that wrap past the antipode are deduplicated, so any degree
/// < M stays valid.
fn ring(m: usize, degree: usize) -> Vec<Vec<usize>> {
    let mut edges = Vec::new();
    for i in 0..m {
        for d in 1..=degree {
            edges.push((i, (i + d) % m));
        }
    }
    to_neighbors(m, &edges)
}

/// 2-D torus on the most-square factorization r × c of M (largest divisor
/// r <= sqrt(M)). M prime gives r = 1, which degenerates to a ring.
fn torus(m: usize) -> Vec<Vec<usize>> {
    let mut r = 1;
    let mut d = 1;
    while d * d <= m {
        if m % d == 0 {
            r = d;
        }
        d += 1;
    }
    let c = m / r;
    let mut edges = Vec::new();
    for row in 0..r {
        for col in 0..c {
            let i = row * c + col;
            edges.push((i, row * c + (col + 1) % c)); // right
            edges.push((i, ((row + 1) % r) * c + col)); // down
        }
    }
    to_neighbors(m, &edges)
}

fn star(m: usize) -> Vec<Vec<usize>> {
    let edges: Vec<(usize, usize)> = (1..m).map(|i| (0, i)).collect();
    to_neighbors(m, &edges)
}

/// G(M, p) with counter-based edge draws; deterministically resampled with
/// a fresh attempt salt until connected (up to 100 attempts), then — as a
/// last resort for very sparse p — minimally augmented by linking the
/// connected components' smallest members in a chain.
fn erdos_renyi(m: usize, p: f64, seed: u64) -> Vec<Vec<usize>> {
    let sample = |attempt: u64| -> Vec<Vec<usize>> {
        let mut edges = Vec::new();
        for i in 0..m {
            for j in (i + 1)..m {
                let pair = (i * m + j) as u64;
                let mut rng = counter_rng(seed, 0xE2D0_0001, pair, attempt);
                if rng.f64() < p {
                    edges.push((i, j));
                }
            }
        }
        to_neighbors(m, &edges)
    };
    let mut last = sample(0);
    for attempt in 0..100u64 {
        let nb = if attempt == 0 { last.clone() } else { sample(attempt) };
        let g = Graph {
            family: GraphFamily::ErdosRenyi,
            neighbors: nb.clone(),
        };
        if g.is_connected() {
            return nb;
        }
        last = nb;
    }
    augment_connected(m, last)
}

/// Connect the components of a disconnected neighbor structure by chaining
/// their smallest members (deterministic, adds the minimum number of edges).
fn augment_connected(m: usize, mut nb: Vec<Vec<usize>>) -> Vec<Vec<usize>> {
    let mut comp = vec![usize::MAX; m];
    let mut reps = Vec::new();
    for start in 0..m {
        if comp[start] != usize::MAX {
            continue;
        }
        let id = reps.len();
        reps.push(start);
        comp[start] = id;
        let mut queue = vec![start];
        while let Some(i) = queue.pop() {
            for &j in &nb[i] {
                if comp[j] == usize::MAX {
                    comp[j] = id;
                    queue.push(j);
                }
            }
        }
    }
    for pair in reps.windows(2) {
        nb[pair[0]].push(pair[1]);
        nb[pair[1]].push(pair[0]);
    }
    for list in nb.iter_mut() {
        list.sort_unstable();
        list.dedup();
    }
    nb
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MixingRule;

    fn topo(family: GraphFamily) -> TopologyConfig {
        TopologyConfig {
            family,
            degree: 1,
            p: 0.4,
            mixing: MixingRule::Metropolis,
            seed: 7,
        }
    }

    #[test]
    fn full_graph_everyone_adjacent() {
        let g = Graph::build(&topo(GraphFamily::Full), 6, 1);
        for i in 0..6 {
            assert_eq!(g.degree(i), 5);
            assert_eq!(g.closed_neighborhood(i), (0..6).collect::<Vec<_>>());
        }
        assert_eq!(g.edge_count(), 15);
    }

    #[test]
    fn ring_degrees_and_wraparound() {
        let g = Graph::build(&topo(GraphFamily::Ring), 7, 1);
        for i in 0..7 {
            assert_eq!(g.degree(i), 2, "cycle degree");
        }
        assert_eq!(g.neighbors(0), &[1, 6]);
        // Wider ring: degree 2 each side.
        let t = TopologyConfig {
            degree: 2,
            ..topo(GraphFamily::Ring)
        };
        let g2 = Graph::build(&t, 7, 1);
        assert_eq!(g2.neighbors(0), &[1, 2, 5, 6]);
    }

    #[test]
    fn ring_m2_deduplicates() {
        let g = Graph::build(&topo(GraphFamily::Ring), 2, 1);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[0]);
        assert!(g.is_connected());
    }

    #[test]
    fn torus_is_most_square() {
        // M = 9 → 3×3 torus, degree 4 everywhere.
        let g = Graph::build(&topo(GraphFamily::Torus), 9, 1);
        for i in 0..9 {
            assert_eq!(g.degree(i), 4, "node {i}");
        }
        // M = 6 → 2×3; the row dimension 2 dedupes up == down.
        let g6 = Graph::build(&topo(GraphFamily::Torus), 6, 1);
        assert!(g6.is_connected());
        // Prime M degenerates to a ring.
        let g7 = Graph::build(&topo(GraphFamily::Torus), 7, 1);
        assert_eq!(g7.max_degree(), 2);
        assert!(g7.is_connected());
    }

    #[test]
    fn star_hub_and_spokes() {
        let g = Graph::build(&topo(GraphFamily::Star), 8, 1);
        assert_eq!(g.degree(0), 7);
        for i in 1..8 {
            assert_eq!(g.neighbors(i), &[0]);
        }
        assert!(g.is_connected());
    }

    #[test]
    fn erdos_renyi_deterministic_and_connected() {
        let a = Graph::build(&topo(GraphFamily::ErdosRenyi), 12, 1);
        let b = Graph::build(&topo(GraphFamily::ErdosRenyi), 12, 1);
        for i in 0..12 {
            assert_eq!(a.neighbors(i), b.neighbors(i));
        }
        assert!(a.is_connected());
        // Even at very sparse p the builder must hand back something
        // connected (augmentation fallback).
        let sparse = TopologyConfig {
            p: 0.01,
            ..topo(GraphFamily::ErdosRenyi)
        };
        let g = Graph::build(&sparse, 16, 3);
        assert!(g.is_connected());
    }

    #[test]
    fn topology_seed_zero_falls_back_to_run_seed() {
        let zero_seed = TopologyConfig {
            seed: 0,
            ..topo(GraphFamily::ErdosRenyi)
        };
        let a = Graph::build(&zero_seed, 10, 42);
        let b = Graph::build(&zero_seed, 10, 42);
        let c = Graph::build(&zero_seed, 10, 43);
        let edges = |g: &Graph| (0..10).map(|i| g.neighbors(i).to_vec()).collect::<Vec<_>>();
        assert_eq!(edges(&a), edges(&b));
        // A different run seed draws a different graph (with high
        // probability at p = 0.4, M = 10; pinned for these seeds).
        assert_ne!(edges(&a), edges(&c));
    }

    #[test]
    fn pair_ids_are_symmetric_and_distinct() {
        let g = Graph::build(&topo(GraphFamily::Full), 5, 1);
        assert_eq!(g.pair_id(1, 3), g.pair_id(3, 1));
        assert_ne!(g.pair_id(0, 1), g.pair_id(0, 2));
        assert_ne!(g.pair_id(1, 2), g.pair_id(0, 3));
    }

    #[test]
    fn closed_neighborhood_sorted_with_self() {
        let g = Graph::build(&topo(GraphFamily::Ring), 5, 1);
        assert_eq!(g.closed_neighborhood(2), vec![1, 2, 3]);
        assert_eq!(g.closed_neighborhood(0), vec![0, 1, 4]);
        assert_eq!(g.closed_neighborhood(4), vec![0, 3, 4]);
    }
}
