#!/usr/bin/env python3
"""Gate bench regressions: compare a fresh BENCH_*.json against a baseline.

Usage:
    bench_compare.py BASELINE.json FRESH.json [--fail-over RATIO]
    bench_compare.py --self-gate FRESH.json [--fail-over RATIO]

Compares entries by name on mean_ns. An entry whose fresh mean exceeds
``RATIO x`` its baseline mean (default 2.0 -- generous, because shared CI
runners are noisy) counts as a regression and fails the script. Entries
present on only one side are reported but never fail the gate (kernels are
added and retired across PRs).

``--self-gate`` takes a *single* file and compares each optimized kernel
against its reference formulation measured in the same run: every entry
whose name carries a parenthetical containing "reference" (e.g.
``dot d=7850 (reference scalar)``) is paired with the entry named by the
same base (``dot d=7850``, or the unique non-reference entry extending
it, e.g. ``minibatch gradient B=200 (tiled)``). The optimized side must
not be slower than ``RATIO x`` the reference. Because both sides come
from one process on one host, the self-gate is host-independent and
needs no committed measured baseline.

A baseline with ``unix_time == 0`` is an *estimated* seed -- numbers that
were never measured on real hardware (authored on a host without the
toolchain). Ratios against invented nanoseconds are not evidence of a
regression, so against such a baseline the script prints the full
comparison plus any would-be regressions and exits 0 (report-only). The
gate arms itself automatically the first time a measured baseline
(``unix_time > 0``, e.g. from the ``bench-components-json`` CI artifact)
is committed.

Exit status: 0 = no regression (or estimated baseline, report-only),
1 = at least one regression, 2 = bad input.
"""

from __future__ import annotations

import argparse
import json
import sys


def load_doc(path: str) -> dict:
    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as exc:
        print(f"error: cannot read {path}: {exc}", file=sys.stderr)
        sys.exit(2)
    if not isinstance(doc, dict) or not isinstance(doc.get("results"), list):
        print(f"error: {path} has no 'results' array", file=sys.stderr)
        sys.exit(2)
    return doc


def results_index(doc: dict) -> dict[str, dict]:
    out: dict[str, dict] = {}
    for entry in doc["results"]:
        name = entry.get("name")
        if isinstance(name, str) and isinstance(entry.get("mean_ns"), (int, float)):
            out[name] = entry
    return out


def is_estimated(doc: dict) -> bool:
    """True when the baseline was seeded without real measurements."""
    ts = doc.get("unix_time", 0)
    return not isinstance(ts, (int, float)) or ts == 0


def fmt_ns(ns: float) -> str:
    if ns < 1e3:
        return f"{ns:.0f} ns"
    if ns < 1e6:
        return f"{ns / 1e3:.2f} us"
    if ns < 1e9:
        return f"{ns / 1e6:.2f} ms"
    return f"{ns / 1e9:.3f} s"


def split_reference(name: str) -> str | None:
    """Base name for a reference entry, or None if it is not one.

    A reference entry carries a parenthetical containing the word
    "reference": strip that parenthetical (and surrounding whitespace)
    to get the base shared with the optimized counterpart.
    """
    start = name.rfind("(")
    if start < 0 or not name.endswith(")"):
        return None
    if "reference" not in name[start:].lower():
        return None
    return name[:start].strip()


def pair_optimized(base: str, index: dict[str, dict]) -> str | None:
    """The optimized counterpart of a reference entry's base name."""
    if base in index and split_reference(base) is None:
        return base
    candidates = [
        n
        for n in index
        if n.startswith(base) and split_reference(n) is None and n != base
    ]
    return candidates[0] if len(candidates) == 1 else None


def self_gate(path: str, fail_over: float) -> int:
    doc = load_doc(path)
    index = results_index(doc)
    estimated = is_estimated(doc)

    pairs = []
    unpaired = []
    for name in sorted(index):
        base = split_reference(name)
        if base is None:
            continue
        opt = pair_optimized(base, index)
        if opt is None:
            unpaired.append(name)
            continue
        pairs.append((opt, name))

    if not pairs:
        print(f"error: no optimized/reference pairs found in {path}", file=sys.stderr)
        return 2

    regressions = []
    print(f"{'optimized kernel':<56} {'optimized':>12} {'reference':>12} {'ratio':>8}")
    for opt, ref in pairs:
        o_ns, r_ns = float(index[opt]["mean_ns"]), float(index[ref]["mean_ns"])
        ratio = o_ns / r_ns if r_ns > 0 else float("inf")
        flag = ""
        if ratio > fail_over:
            regressions.append((opt, ratio))
            flag = "  << SLOWER THAN REFERENCE"
        print(f"{opt:<56} {fmt_ns(o_ns):>12} {fmt_ns(r_ns):>12} {ratio:>7.2f}x{flag}")
    for name in unpaired:
        print(f"note: no unique optimized counterpart for {name!r}; skipped")

    if regressions:
        print(
            f"\n{len(regressions)} optimized kernel(s) slower than "
            f"{fail_over:.2f}x their same-run reference:",
            file=sys.stderr,
        )
        for name, ratio in regressions:
            print(f"  {name}: {ratio:.2f}x", file=sys.stderr)
        if estimated:
            print(
                "\nfile is an estimated seed (unix_time == 0), never measured -- "
                "reporting only, not failing. The self-gate arms on the first "
                "measured run."
            )
            return 0
        return 1
    print(
        f"\nself-gate clean: {len(pairs)} optimized kernel(s) within "
        f"{fail_over:.2f}x of their reference"
        + (" (estimated seed, unarmed)" if estimated else "")
    )
    return 0


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("baseline", help="committed baseline BENCH_*.json")
    parser.add_argument(
        "fresh", nargs="?", help="freshly generated BENCH_*.json (omit with --self-gate)"
    )
    parser.add_argument(
        "--self-gate",
        action="store_true",
        help="compare optimized vs reference pairs within the single given file",
    )
    parser.add_argument(
        "--fail-over",
        type=float,
        default=2.0,
        metavar="RATIO",
        help="fail when fresh mean > RATIO x baseline mean (default: 2.0)",
    )
    args = parser.parse_args()
    if args.fail_over <= 0:
        print("error: --fail-over must be positive", file=sys.stderr)
        return 2
    if args.self_gate:
        if args.fresh is not None:
            print("error: --self-gate takes exactly one file", file=sys.stderr)
            return 2
        return self_gate(args.baseline, args.fail_over)
    if args.fresh is None:
        print("error: FRESH.json required without --self-gate", file=sys.stderr)
        return 2

    base_doc = load_doc(args.baseline)
    fresh_doc = load_doc(args.fresh)
    base = results_index(base_doc)
    fresh = results_index(fresh_doc)
    estimated = is_estimated(base_doc)

    regressions = []
    print(f"{'kernel':<56} {'baseline':>12} {'fresh':>12} {'ratio':>8}")
    for name in sorted(base.keys() | fresh.keys()):
        b = base.get(name)
        f = fresh.get(name)
        if b is None:
            print(f"{name:<56} {'(new)':>12} {fmt_ns(f['mean_ns']):>12} {'-':>8}")
            continue
        if f is None:
            print(f"{name:<56} {fmt_ns(b['mean_ns']):>12} {'(gone)':>12} {'-':>8}")
            continue
        b_ns, f_ns = float(b["mean_ns"]), float(f["mean_ns"])
        ratio = f_ns / b_ns if b_ns > 0 else float("inf")
        flag = ""
        if ratio > args.fail_over:
            regressions.append((name, ratio))
            flag = "  << REGRESSION"
        print(f"{name:<56} {fmt_ns(b_ns):>12} {fmt_ns(f_ns):>12} {ratio:>7.2f}x{flag}")

    if regressions:
        print(
            f"\n{len(regressions)} kernel(s) regressed more than "
            f"{args.fail_over:.2f}x vs baseline:",
            file=sys.stderr,
        )
        for name, ratio in regressions:
            print(f"  {name}: {ratio:.2f}x", file=sys.stderr)
        if estimated:
            print(
                "\nbaseline is an estimated seed (unix_time == 0), never measured "
                "on real hardware -- reporting only, not failing. Commit a measured "
                "run (the bench-components-json CI artifact) to arm the gate.",
            )
            return 0
        return 1
    if estimated:
        print(
            f"\nno regressions beyond {args.fail_over:.2f}x ({len(fresh)} fresh "
            "entries; baseline is an estimated seed, gate unarmed)"
        )
        return 0
    print(f"\nno regressions beyond {args.fail_over:.2f}x ({len(fresh)} fresh entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
