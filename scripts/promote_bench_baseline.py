#!/usr/bin/env python3
"""Promote a measured components-bench run to the committed baseline.

Usage:
    promote_bench_baseline.py FRESH.json [--baseline BENCH_components.json]
    promote_bench_baseline.py FRESH.json --check

The committed ``BENCH_components.json`` at the repo root seeds the CI
regression gate (``scripts/bench_compare.py``). The tree's original
baseline is *estimated* (``unix_time == 0``) because it was authored on
a host without the toolchain, which leaves the cross-run gate unarmed.
This script arms it: download the ``bench-components-json`` artifact
from a green CI run (or run ``cargo bench --bench components`` locally)
and promote it.

Validation before anything is overwritten:

* the fresh file parses and carries a non-empty ``results`` array;
* ``unix_time > 0`` -- only *measured* runs may become the baseline;
* every kernel name in the committed baseline is still present in the
  fresh run (kernels may be added freely; a kernel that *vanished*
  usually means a partial bench run, so it must be acknowledged with
  ``--allow-missing``).

``--check`` performs the validation and prints the verdict without
writing. Exit status: 0 = promoted (or check passed), 1 = validation
failed, 2 = bad input.
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path


def load_doc(path: Path) -> dict:
    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as exc:
        print(f"error: cannot read {path}: {exc}", file=sys.stderr)
        sys.exit(2)
    if not isinstance(doc, dict) or not isinstance(doc.get("results"), list):
        print(f"error: {path} has no 'results' array", file=sys.stderr)
        sys.exit(2)
    return doc


def names(doc: dict) -> set[str]:
    return {
        e["name"]
        for e in doc["results"]
        if isinstance(e, dict) and isinstance(e.get("name"), str)
    }


def main() -> int:
    repo_root = Path(__file__).resolve().parent.parent
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("fresh", type=Path, help="measured BENCH_components.json artifact")
    parser.add_argument(
        "--baseline",
        type=Path,
        default=repo_root / "BENCH_components.json",
        help="committed baseline to replace (default: repo root)",
    )
    parser.add_argument(
        "--check",
        action="store_true",
        help="validate only; do not write the baseline",
    )
    parser.add_argument(
        "--allow-missing",
        action="store_true",
        help="permit baseline kernels absent from the fresh run",
    )
    args = parser.parse_args()

    fresh = load_doc(args.fresh)
    fresh_names = names(fresh)
    failures: list[str] = []

    if not fresh_names:
        failures.append("fresh run has no named results")
    ts = fresh.get("unix_time", 0)
    if not isinstance(ts, (int, float)) or ts <= 0:
        failures.append(
            f"unix_time is {ts!r}: only measured runs (unix_time > 0) may "
            "become the baseline"
        )
    bad_means = [
        e.get("name", "?")
        for e in fresh["results"]
        if not isinstance(e.get("mean_ns"), (int, float)) or e.get("mean_ns", 0) <= 0
    ]
    if bad_means:
        failures.append(f"non-positive or missing mean_ns: {sorted(bad_means)}")

    if args.baseline.exists():
        missing = sorted(names(load_doc(args.baseline)) - fresh_names)
        if missing and not args.allow_missing:
            failures.append(
                f"{len(missing)} baseline kernel(s) absent from the fresh run "
                f"(pass --allow-missing to acknowledge): {missing}"
            )
    else:
        print(f"note: no existing baseline at {args.baseline}; promoting fresh run as-is")

    if failures:
        for f in failures:
            print(f"error: {f}", file=sys.stderr)
        return 1

    print(f"fresh run: {len(fresh_names)} kernels, unix_time={ts}")
    if args.check:
        print("check passed; not writing (drop --check to promote)")
        return 0
    shutil.copyfile(args.fresh, args.baseline)
    print(f"promoted {args.fresh} -> {args.baseline} (cross-run gate is now armed)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
