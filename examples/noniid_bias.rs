//! Non-IID robustness (the paper's Fig. 2b claim): when every device only
//! holds two classes, analog over-the-air aggregation degrades far less
//! than the digital schemes.
//!
//! ```bash
//! cargo run --release --example noniid_bias
//! ```

use ota_dsgd::config::{presets, DatasetSpec, RunConfig, Scheme};
use ota_dsgd::coordinator::Trainer;
use ota_dsgd::data::{load_corpus, partition};
use ota_dsgd::util::rng::Pcg64;

fn cfg_for(scheme: Scheme, noniid: bool) -> RunConfig {
    RunConfig {
        scheme,
        // M = 20: over-the-air aggregation needs enough superposed devices
        // for the analog sum to dominate the channel noise — and the
        // non-IID robustness claim is about averaging over many biased
        // shards (2 classes each, so ≥ 10 devices to cover 10 classes
        // redundantly).
        devices: 20,
        local_samples: 300,
        channel_uses: presets::MODEL_DIM / 2,
        sparsity: presets::MODEL_DIM / 4,
        pbar: 500.0,
        iterations: 30,
        eval_every: 5,
        noniid,
        mean_removal_rounds: 5,
        dataset: DatasetSpec::Synthetic {
            train: 8_000,
            test: 1_500,
        },
        ..RunConfig::default()
    }
}

fn main() -> anyhow::Result<()> {
    // Show what the bias looks like first.
    let sample_cfg = cfg_for(Scheme::ADsgd, true);
    let corpus = load_corpus(&sample_cfg.dataset, sample_cfg.seed)?;
    let mut rng = Pcg64::new(1);
    let shards = partition::non_iid(&corpus.train, 20, 300, &mut rng);
    println!("non-IID shard label diversity (classes per device):");
    for (i, shard) in shards.iter().enumerate() {
        print!(
            "  dev{i}: {}",
            partition::distinct_labels(&corpus.train, shard)
        );
    }
    println!("\n");

    println!(
        "{:<10} {:>12} {:>12} {:>12}",
        "scheme", "IID acc", "non-IID acc", "degradation"
    );
    let mut rows = Vec::new();
    for scheme in [Scheme::ADsgd, Scheme::DDsgd, Scheme::SignSgd, Scheme::Qsgd] {
        let acc_iid = Trainer::new(cfg_for(scheme, false))?.run().best_accuracy();
        let acc_bias = Trainer::new(cfg_for(scheme, true))?.run().best_accuracy();
        println!(
            "{:<10} {:>12.4} {:>12.4} {:>12.4}",
            scheme.name(),
            acc_iid,
            acc_bias,
            acc_iid - acc_bias
        );
        rows.push((scheme, acc_iid, acc_bias));
    }
    let best_biased = rows
        .iter()
        .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
        .unwrap();
    println!(
        "\nBest scheme under bias: {} ({:.4}).\n\
         Paper (Fig. 2b): A-DSGD stays the strongest scheme under 2-class\n\
         device bias and D-DSGD beats SignSGD/QSGD. At this reduced scale\n\
         A-DSGD's *absolute* lead survives; its raw degradation number is\n\
         larger than at the paper's M=25/B=1000 scale (`repro fig 2`),\n\
         where its degradation is also the smallest.",
        best_biased.0.name(),
        best_biased.2
    );
    Ok(())
}
