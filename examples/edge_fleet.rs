//! End-to-end driver (DESIGN.md §6 validation ladder, step 4): a fleet of
//! wireless edge devices trains the paper's d = 7850 classifier on a real
//! small workload — the full synthetic MNIST-like corpus — under all seven
//! transmission schemes (error-free, A-DSGD, fading/blind A-DSGD, D-DSGD,
//! SignSGD, QSGD), logging the loss/accuracy curves side by side and
//! auditing the Eq. 6 power constraint. The fading runs model the realistic
//! edge fleet: Rayleigh per-device gains, CSI truncated inversion, and a
//! round deadline that drops stragglers.
//!
//! This run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! cargo run --release --example edge_fleet [-- --iterations 40]
//! ```

use ota_dsgd::config::{presets, DatasetSpec, FadingDist, LinkKind, RunConfig, Scheme};
use ota_dsgd::coordinator::Trainer;
use ota_dsgd::util::cli::Args;

fn fleet_config(scheme: Scheme, iterations: usize) -> RunConfig {
    let mut cfg = RunConfig {
        scheme,
        devices: 15,
        local_samples: 400,
        channel_uses: presets::MODEL_DIM / 4,
        sparsity: presets::MODEL_DIM / 8,
        pbar: 500.0,
        iterations,
        eval_every: 4,
        mean_removal_rounds: 5,
        dataset: DatasetSpec::Synthetic {
            train: 8_000,
            test: 2_000,
        },
        ..RunConfig::default()
    };
    if scheme.kind() == LinkKind::Fading {
        cfg.fading = FadingDist::Rayleigh;
        cfg.csi_threshold = 0.2;
        cfg.latency_mean_secs = 0.005;
        cfg.deadline_secs = 0.02;
    }
    cfg
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let iterations = args.usize("iterations", 40);
    let mut results = Vec::new();

    for scheme in [
        Scheme::ErrorFree,
        Scheme::ADsgd,
        Scheme::FadingADsgd,
        Scheme::BlindADsgd,
        Scheme::DDsgd,
        Scheme::SignSgd,
        Scheme::Qsgd,
    ] {
        let cfg = fleet_config(scheme, iterations);
        println!("\n=== {} [{} link] ===", cfg.summary(), scheme.kind().name());
        let mut trainer = Trainer::new(cfg)?;
        trainer.verbose = true;
        let log = trainer.run();
        anyhow::ensure!(
            log.power_constraint_ok(1e-6),
            "{} violated the power constraint",
            scheme.name()
        );
        if scheme.kind() == LinkKind::Fading {
            let modeled = log
                .records
                .iter()
                .all(|r| r.participation.is_some_and(|p| p.total() == 15));
            anyhow::ensure!(modeled, "{} lost participation telemetry", scheme.name());
        }
        let path = format!("results/edge_fleet/{}.csv", scheme.name().replace(' ', "_"));
        log.write_csv(&path)?;
        println!("series → {path}");
        results.push((scheme, log));
    }

    println!("\n=== fleet summary ({iterations} iterations) ===");
    println!(
        "{:<12} {:>10} {:>10} {:>12} {:>10}",
        "scheme", "final", "best", "avg power", "secs"
    );
    for (scheme, log) in &results {
        println!(
            "{:<12} {:>10.4} {:>10.4} {:>12.1} {:>10.1}",
            scheme.name(),
            log.final_accuracy,
            log.best_accuracy(),
            log.measured_avg_power.iter().sum::<f64>()
                / log.measured_avg_power.len().max(1) as f64,
            log.total_secs
        );
    }

    // The paper's qualitative expectation: error-free ≥ A-DSGD ≥ digital.
    let acc: Vec<f64> = results.iter().map(|(_, l)| l.best_accuracy()).collect();
    anyhow::ensure!(acc[1] > 0.5, "A-DSGD should learn (got {})", acc[1]);
    let standings: Vec<String> = results
        .iter()
        .map(|(s, l)| format!("{} {:.4}", s.name(), l.best_accuracy()))
        .collect();
    println!("\nedge_fleet OK ({})", standings.join(", "));
    Ok(())
}
