//! Bandwidth budgeting for an IoT deployment: given a fixed latency budget
//! (total channel symbols), how should a designer split it between channel
//! uses per round (s) and number of rounds (T)? Reproduces the Fig. 7
//! trade-off on a compressed scale and prints the capacity arithmetic a
//! digital design would face at the same budget (Eq. 8).
//!
//! ```bash
//! cargo run --release --example bandwidth_budget
//! ```

use ota_dsgd::config::{presets, DatasetSpec, RunConfig, Scheme};
use ota_dsgd::coordinator::Trainer;
use ota_dsgd::digital::capacity_bits;

fn main() -> anyhow::Result<()> {
    let d = presets::MODEL_DIM;
    // Fig. 7's operating point: M = 25 devices (enough superposition for
    // P̄ = 50), k = 4s/5, and a symbol budget worth 24 wide rounds.
    let symbol_budget = 24 * (d / 2);
    let pbar = 50.0;

    println!("total symbol budget: {symbol_budget} (d = {d})");
    println!(
        "\n{:>8} {:>6} {:>8} {:>12} {:>12}",
        "s", "T", "k", "digital R_t", "final acc"
    );

    let mut outcomes: Vec<(usize, f64)> = Vec::new();
    for divisor in [10usize, 5, 2] {
        let s = d / divisor;
        let iterations = (symbol_budget / s).max(2);
        let cfg = RunConfig {
            scheme: Scheme::ADsgd,
            devices: 25,
            local_samples: 400,
            channel_uses: s,
            sparsity: 4 * s / 5,
            pbar,
            iterations,
            eval_every: 4,
            mean_removal_rounds: 3,
            dataset: DatasetSpec::Synthetic {
                train: 10_000,
                test: 1_000,
            },
            ..RunConfig::default()
        };
        let budget_bits = capacity_bits(s, cfg.devices, pbar, cfg.noise_var);
        let mut trainer = Trainer::new(cfg.clone())?;
        let log = trainer.run();
        println!(
            "{:>8} {:>6} {:>8} {:>12.1} {:>12.4}",
            s, iterations, cfg.sparsity, budget_bits, log.best_accuracy()
        );
        outcomes.push((s, log.best_accuracy()));
    }

    let winner = outcomes
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!(
        "\nBest use of the budget: s = {} (accuracy {:.4}).\n\
         Paper Fig. 7b: at a fixed symbol budget, mid-bandwidth rounds\n\
         (s = d/5) beat wide ones (s = d/2), but the trend breaks at very\n\
         small s where k = 4s/5 exceeds what AMP can recover.",
        winner.0, winner.1
    );
    Ok(())
}
