//! Quickstart: train a model collaboratively over the simulated wireless
//! MAC with A-DSGD in under a minute.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ota_dsgd::config::{presets, Scheme};
use ota_dsgd::coordinator::Trainer;

fn main() -> anyhow::Result<()> {
    // The smoke preset: 5 devices, 120 samples each, s = d/8 channel uses,
    // P̄ = 500, 10 iterations. Everything scales from this one struct.
    let mut cfg = presets::smoke();
    cfg.scheme = Scheme::ADsgd;
    cfg.iterations = 20;
    println!("config: {}", cfg.summary());
    println!("transmission pipeline: {} link", cfg.scheme.kind().name());

    let mut trainer = Trainer::new(cfg)?;
    trainer.verbose = true;
    let log = trainer.run();

    println!("\naccuracy curve:");
    for (t, acc) in log.accuracy_series() {
        println!("  t={t:<3} acc={acc:.4}");
    }
    println!(
        "\nfinal accuracy {:.4}; per-device avg power {:.1} (P̄ = {}); power-ok {}",
        log.final_accuracy,
        log.measured_avg_power[0],
        log.pbar,
        log.power_constraint_ok(1e-6),
    );
    anyhow::ensure!(
        log.final_accuracy > 0.5,
        "quickstart should comfortably beat chance"
    );
    println!("quickstart OK");
    Ok(())
}
