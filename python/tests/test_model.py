"""L2 graph correctness: closed-form per-device gradients vs jax.grad,
AMP step vs the reference loop body."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

settings.register_profile("model", deadline=None, max_examples=10)
settings.load_profile("model")


def rand_state(seed, m, b):
    rng = np.random.default_rng(seed)
    params = rng.normal(0, 0.05, model.PARAM_DIM).astype(np.float32)
    imgs = rng.random((m, b, model.IMG)).astype(np.float32)
    labels = np.eye(model.CLASSES, dtype=np.float32)[
        rng.integers(0, model.CLASSES, (m, b))
    ]
    return params, imgs, labels


@given(st.integers(1, 6), st.integers(1, 40), st.integers(0, 2**31 - 1))
def test_closed_form_grads_match_autodiff(m, b, seed):
    params, imgs, labels = rand_state(seed, m, b)
    got = model.per_device_grads(
        jnp.asarray(params), jnp.asarray(imgs), jnp.asarray(labels)
    )
    want = ref.per_device_grads_ref(
        jnp.asarray(params), jnp.asarray(imgs), jnp.asarray(labels)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-6)


def test_grads_shape_and_zero_params_symmetry():
    params = np.zeros(model.PARAM_DIM, np.float32)
    _, imgs, labels = rand_state(0, 3, 10)
    g = np.asarray(
        model.per_device_grads(jnp.asarray(params), jnp.asarray(imgs), jnp.asarray(labels))
    )
    assert g.shape == (3, model.PARAM_DIM)
    # At θ=0 softmax is uniform: db_c = mean(1/10 − 1{y=c}).
    gb = g[:, model.IMG * model.CLASSES :]
    counts = labels.sum(axis=1) / labels.shape[1]  # [3, 10]
    np.testing.assert_allclose(gb, 0.1 - counts, atol=1e-6)


def test_gradient_descent_reduces_loss():
    params, imgs, labels = rand_state(3, 2, 30)
    p = jnp.asarray(params)
    imgs_j, labels_j = jnp.asarray(imgs), jnp.asarray(labels)
    flat_imgs = imgs_j.reshape(-1, model.IMG)
    flat_labels = labels_j.reshape(-1, model.CLASSES)
    l0 = float(ref.loss_ref(p, flat_imgs, flat_labels))
    for _ in range(10):
        g = model.per_device_grads(p, imgs_j, labels_j)
        p = p - 0.1 * jnp.mean(g, axis=0)
    l1 = float(ref.loss_ref(p, flat_imgs, flat_labels))
    assert l1 < l0


@given(st.integers(10, 60), st.integers(30, 160), st.integers(0, 2**31 - 1))
def test_amp_step_matches_ref(s_tilde, d, seed):
    rng = np.random.default_rng(seed)
    a = (rng.normal(0, 1, (s_tilde, d)) / np.sqrt(s_tilde)).astype(np.float32)
    x_true = np.zeros(d, np.float32)
    idx = rng.choice(d, size=max(1, d // 10), replace=False)
    x_true[idx] = rng.normal(0, 1, len(idx))
    y = (a @ x_true).astype(np.float32)
    x0 = np.zeros(d, np.float32)
    got = model.amp_step(jnp.asarray(a), jnp.asarray(y), jnp.asarray(x0), jnp.asarray(y), 1.1)
    want = ref.amp_step_ref(jnp.asarray(a), jnp.asarray(y), jnp.asarray(x0), jnp.asarray(y), 1.1)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=2e-4)


def test_amp_iterations_recover_sparse_signal():
    """Iterating the L2 amp_step graph must actually solve the CS problem."""
    rng = np.random.default_rng(7)
    s_tilde, d, k = 120, 300, 12
    a = (rng.normal(0, 1, (s_tilde, d)) / np.sqrt(s_tilde)).astype(np.float32)
    x_true = np.zeros(d, np.float32)
    idx = rng.choice(d, size=k, replace=False)
    x_true[idx] = rng.normal(0, 1, k)
    y = (a @ x_true).astype(np.float32)
    x = jnp.zeros(d, jnp.float32)
    r = jnp.asarray(y)
    for _ in range(40):
        x, r, _ = model.amp_step(jnp.asarray(a), jnp.asarray(y), x, r, 1.1)
    err = np.linalg.norm(np.asarray(x) - x_true) / np.linalg.norm(x_true)
    assert err < 0.05, f"relative error {err}"
