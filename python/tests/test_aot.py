"""AOT lowering: the HLO-text artifacts must be produced, parseable, and
carry the expected entry signatures."""

import os
import subprocess
import sys

import pytest

from compile import aot, model


def test_grad_lowering_produces_hlo_text():
    text = aot.lower_grad(2, 7)
    assert "HloModule" in text
    # Entry signature embeds the input shapes.
    assert "f32[7850]" in text
    assert "f32[2,7,784]" in text
    assert "f32[2,7,10]" in text
    # Output: per-device gradients [2, 7850] inside the result tuple.
    assert "f32[2,7850]" in text


def test_projection_lowering_shapes():
    text = aot.lower_projection(33, 95)
    assert "HloModule" in text
    assert "f32[33,95]" in text
    assert "f32[95]" in text


def test_amp_step_lowering_shapes():
    text = aot.lower_amp_step(20, 50)
    assert "HloModule" in text
    assert "f32[20,50]" in text


def test_cli_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out",
            out,
            "--grad-shapes",
            "2x5",
            "--proj-shape",
            "9x30",
        ],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = open(os.path.join(out, "manifest.txt")).read()
    assert "kind=grad" in manifest
    assert "devices=2 batch=5" in manifest
    assert "kind=projection" in manifest
    assert "kind=amp_step" in manifest
    for line in manifest.splitlines():
        if line.startswith("#") or not line.strip():
            continue
        fname = dict(tok.split("=", 1) for tok in line.split()).get("file")
        assert os.path.exists(os.path.join(out, fname)), fname


def test_param_dim_matches_rust():
    # rust/src/model/mod.rs PARAM_DIM — keep the two layers in lockstep.
    assert model.PARAM_DIM == 7850
