"""L1 Pallas kernels vs pure-jnp oracles (hypothesis sweeps shapes/values)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import elementwise, matmul, projection, ref

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")


def assert_close(a, b, atol=1e-4, rtol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


@st.composite
def matmul_shapes(draw):
    m = draw(st.integers(1, 200))
    k = draw(st.integers(1, 150))
    n = draw(st.integers(1, 60))
    return m, k, n


@given(matmul_shapes(), st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(shape, seed):
    m, k, n = shape
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (m, k)).astype(np.float32)
    w = rng.normal(0, 1, (k, n)).astype(np.float32)
    got = matmul.matmul(jnp.asarray(x), jnp.asarray(w))
    want = ref.matmul_ref(jnp.asarray(x), jnp.asarray(w))
    assert_close(got, want, atol=1e-3 * max(1, k // 32))


@given(st.integers(1, 300), st.integers(1, 400), st.integers(0, 2**31 - 1))
def test_projection_matches_ref(s_tilde, d, seed):
    rng = np.random.default_rng(seed)
    a = (rng.normal(0, 1, (s_tilde, d)) / np.sqrt(s_tilde)).astype(np.float32)
    g = rng.normal(0, 1, d).astype(np.float32)
    got = projection.project(jnp.asarray(a), jnp.asarray(g))
    want = ref.project_ref(jnp.asarray(a), jnp.asarray(g))
    assert_close(got, want, atol=1e-3)


@given(st.integers(1, 5000), st.floats(0.0, 3.0), st.integers(0, 2**31 - 1))
def test_soft_threshold_matches_ref(n, tau, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, n).astype(np.float32)
    got = elementwise.soft_threshold(jnp.asarray(x), tau)
    want = ref.soft_threshold_ref(jnp.asarray(x), jnp.float32(tau))
    assert_close(got, want, atol=1e-6)


@given(
    st.integers(1, 3000),
    st.floats(-2.0, 2.0),
    st.floats(-2.0, 2.0),
    st.integers(0, 2**31 - 1),
)
def test_axpby_matches_ref(n, a, b, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, n).astype(np.float32)
    y = rng.normal(0, 1, n).astype(np.float32)
    got = elementwise.axpby(a, jnp.asarray(x), b, jnp.asarray(y))
    want = ref.axpby_ref(np.float32(a), x, np.float32(b), y)
    assert_close(got, want, atol=1e-5)


def test_matmul_nonaligned_shapes():
    """Shapes that are not block multiples exercise the padding path."""
    rng = np.random.default_rng(0)
    for (m, k, n) in [(1, 1, 1), (127, 33, 129), (128, 784, 10), (200, 7850, 1)]:
        x = rng.normal(0, 1, (m, k)).astype(np.float32)
        w = rng.normal(0, 1, (k, n)).astype(np.float32)
        assert_close(
            matmul.matmul(jnp.asarray(x), jnp.asarray(w)),
            x @ w,
            atol=1e-2,
        )


def test_matvec_vecmat_forms():
    rng = np.random.default_rng(1)
    a = rng.normal(0, 1, (37, 53)).astype(np.float32)
    v = rng.normal(0, 1, 53).astype(np.float32)
    u = rng.normal(0, 1, 37).astype(np.float32)
    assert_close(matmul.matvec(jnp.asarray(a), jnp.asarray(v)), a @ v, atol=1e-4)
    assert_close(matmul.vecmat(jnp.asarray(u), jnp.asarray(a)), u @ a, atol=1e-4)


def test_soft_threshold_kills_subthreshold():
    x = jnp.asarray(np.array([0.5, -0.5, 2.0, -2.0], np.float32))
    out = np.asarray(elementwise.soft_threshold(x, 1.0))
    assert out[0] == 0.0 and out[1] == 0.0
    assert out[2] == 1.0 and out[3] == -1.0


def test_vmem_estimate_within_tpu_budget():
    """The paper-scale shapes must fit a 16 MiB VMEM budget per instance."""
    # Largest matmul strip: forward logits at M=25, B=1000: (25000, 784)@(784, 10)
    assert matmul.vmem_estimate_bytes(25000, 784, 10) < 16 * 2**20
    # Projection strip at s̃=3924, d=7850 with 128-row blocks:
    assert 4 * (128 * 7850 + 7850) < 16 * 2**20
