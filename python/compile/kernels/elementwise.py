"""L1 Pallas kernels: elementwise ops used by the AMP decoder graph.

`soft_threshold` is AMP's denoiser η_τ; it runs over the full d-length
vector in 1-D VMEM tiles. Trivially vectorizable — on TPU this is VPU work,
tiled to the (8, 128) register file; on CPU we interpret.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _soft_threshold_kernel(x_ref, tau_ref, o_ref):
    x = x_ref[...]
    tau = tau_ref[0]
    mag = jnp.abs(x) - tau
    o_ref[...] = jnp.where(mag > 0, mag * jnp.sign(x), 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def soft_threshold(x: jax.Array, tau: jax.Array, *, block: int = BLOCK) -> jax.Array:
    """η_τ(x) = sign(x)·max(|x|−τ, 0) over a 1-D vector."""
    assert x.ndim == 1
    n = x.shape[0]
    b = min(block, max(n, 1))
    g = -(-n // b)
    xp = jnp.pad(x.astype(jnp.float32), (0, g * b - n))
    tau_arr = jnp.asarray(tau, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _soft_threshold_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((g * b,), jnp.float32),
        interpret=True,
    )(xp, tau_arr)
    return out[:n]


def _axpby_kernel(x_ref, y_ref, ab_ref, o_ref):
    o_ref[...] = ab_ref[0] * x_ref[...] + ab_ref[1] * y_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def axpby(a: jax.Array, x: jax.Array, b: jax.Array, y: jax.Array, *, block: int = BLOCK):
    """a·x + b·y elementwise (the AMP residual update shape)."""
    assert x.shape == y.shape and x.ndim == 1
    n = x.shape[0]
    blk = min(block, max(n, 1))
    g = -(-n // blk)
    pad = g * blk - n
    xp = jnp.pad(x.astype(jnp.float32), (0, pad))
    yp = jnp.pad(y.astype(jnp.float32), (0, pad))
    ab = jnp.stack([jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)])
    out = pl.pallas_call(
        _axpby_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((g * blk,), jnp.float32),
        interpret=True,
    )(xp, yp, ab)
    return out[:n]
