"""Pure-jnp oracles for every L1 kernel and L2 graph.

pytest checks each Pallas kernel against its oracle (hypothesis sweeps the
shapes); the oracles themselves are checked against jax.grad where a
closed-form claim is involved (the per-device gradient).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def project_ref(a, g):
    return jnp.dot(a, g, preferred_element_type=jnp.float32)


def soft_threshold_ref(x, tau):
    mag = jnp.abs(x) - tau
    return jnp.where(mag > 0, mag * jnp.sign(x), 0.0)


def axpby_ref(a, x, b, y):
    return a * x + b * y


def logits_ref(params, images):
    """Single-layer network: images [N,784], params [7850] → [N,10]."""
    w = params[: 784 * 10].reshape(10, 784)
    b = params[784 * 10 :]
    return images @ w.T + b


def loss_ref(params, images, labels_onehot):
    """Mean softmax cross-entropy."""
    lg = logits_ref(params, images)
    logp = jax.nn.log_softmax(lg, axis=-1)
    return -jnp.mean(jnp.sum(labels_onehot * logp, axis=-1))


def per_device_grads_ref(params, images, labels_onehot):
    """Autodiff oracle for the closed-form L2 graph: images [M,B,784]."""
    g = jax.vmap(jax.grad(loss_ref), in_axes=(None, 0, 0))(
        params, images, labels_onehot
    )
    return g


def amp_step_ref(a, y, x, r, threshold_mult):
    """One AMP iteration (mirrors rust amp::recover's loop body)."""
    s = a.shape[0]
    sigma = jnp.linalg.norm(r) / jnp.sqrt(jnp.asarray(s, jnp.float32))
    tau = threshold_mult * sigma
    pseudo = x + a.T @ r
    x_new = soft_threshold_ref(pseudo, tau)
    b = jnp.count_nonzero(x_new).astype(jnp.float32) / s
    r_new = y - a @ x_new + b * r
    return x_new, r_new, tau
