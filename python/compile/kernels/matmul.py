"""L1 Pallas kernel: tiled matmul.

The compute hot-spot of every L2 graph (model forward, the closed-form
backward, the A·g projection, and AMP's Aᵀr) is a dense matmul, so this is
the kernel the whole stack funnels through.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the output into
(BM × BN) blocks; each program instance loads an (BM × K) strip of `x` and a
(K × BN) strip of `w` into VMEM via BlockSpec and feeds the MXU. Block
shapes are chosen so the VMEM footprint
    BM·K + K·BN + BM·BN  floats
stays well under the ~16 MiB/core budget at this paper's shapes (K ≤ 7850:
128·7850·4 B ≈ 3.8 MiB per strip). On CPU we run interpret=True — real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default output-tile shape (MXU-aligned: multiples of 128 feed the
# 128x128 systolic array without padding waste).
BLOCK_M = 128
BLOCK_N = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (BM, BN) output tile: full-K strips are resident in VMEM."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(a: jax.Array, rows: int, cols: int) -> jax.Array:
    pr = rows - a.shape[0]
    pc = cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def matmul(x: jax.Array, w: jax.Array, *, block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """`x @ w` for 2-D f32 arrays via the Pallas kernel.

    Shapes need not be multiples of the block size: inputs are zero-padded
    to the grid and the result is sliced back.
    """
    assert x.ndim == 2 and w.ndim == 2, (x.shape, w.shape)
    assert x.shape[1] == w.shape[0], (x.shape, w.shape)
    m, k = x.shape
    _, n = w.shape
    bm = min(block_m, max(m, 1))
    bn = min(block_n, max(n, 1))
    gm = -(-m // bm)
    gn = -(-n // bn)
    xp = _pad_to(x.astype(jnp.float32), gm * bm, k)
    wp = _pad_to(w.astype(jnp.float32), k, gn * bn)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp)
    return out[:m, :n]


def matvec(a: jax.Array, v: jax.Array) -> jax.Array:
    """`A @ v` through the same kernel (v as an n×1 column)."""
    return matmul(a, v[:, None])[:, 0]


def vecmat(v: jax.Array, a: jax.Array) -> jax.Array:
    """`v @ A` (≡ Aᵀv for the AMP pseudo-data) through the kernel."""
    return matmul(v[None, :], a)[0]


def vmem_estimate_bytes(m: int, k: int, n: int, block_m: int = BLOCK_M, block_n: int = BLOCK_N) -> int:
    """Estimated per-instance VMEM footprint (f32) for DESIGN.md §Perf."""
    bm = min(block_m, m)
    bn = min(block_n, n)
    return 4 * (bm * k + k * bn + bm * bn)
