"""L1 kernel: the A-DSGD random projection `g̃ = A_s̃ · g^sp` (Alg. 1 line 8).

A row-block tiled matvec: the grid walks (s̃/BS) row strips of A; each
program instance holds a (BS × d) strip plus the full g in VMEM. At the
paper's largest shape (s̃ = 3924, d = 7850) a 128-row strip is
128·7850·4 ≈ 3.8 MiB — comfortably inside a TPU core's VMEM, with g itself
31 KiB. The HBM→VMEM schedule (BlockSpec index_map) streams strips exactly
once: the kernel is memory-bound, so the block shape maximizes strip reuse
of g rather than MXU occupancy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _projection_kernel(a_ref, g_ref, o_ref):
    # (BS, d) · (d,) — contract on the last axis.
    o_ref[...] = jnp.dot(
        a_ref[...], g_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_rows",))
def project(a: jax.Array, g: jax.Array, *, block_rows: int = BLOCK_ROWS) -> jax.Array:
    """A @ g for A: [s̃, d], g: [d] → [s̃]."""
    assert a.ndim == 2 and g.ndim == 1 and a.shape[1] == g.shape[0]
    s_tilde, d = a.shape
    br = min(block_rows, max(s_tilde, 1))
    gr = -(-s_tilde // br)
    ap = jnp.pad(a.astype(jnp.float32), ((0, gr * br - s_tilde), (0, 0)))
    out = pl.pallas_call(
        _projection_kernel,
        grid=(gr,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((gr * br,), jnp.float32),
        interpret=True,
    )(ap, g.astype(jnp.float32))
    return out[:s_tilde]
