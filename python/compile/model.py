"""L2 JAX graphs, all funnelling through the L1 Pallas kernels.

Three build-time graphs, AOT-lowered by `aot.py`:

* `per_device_grads` — the paper's device-side computation: batched
  per-device gradients of the single-layer network (d = 7850) in closed
  form. Forward logits AND the backward einsum both run through the
  Pallas matmul kernel, so the entire gradient pipeline exercises L1.
* `project` — the A-DSGD random projection (re-exported kernel).
* `amp_step` — one AMP decoder iteration (projection + elementwise
  kernels), matching `rust/src/amp`'s loop body bit-for-bit in structure.

The closed form used for the gradient (softmax cross-entropy):
    err  = (softmax(XWᵀ + b) − Y) / B         [B, 10]
    ∇W   = errᵀ X                              [10, 784]
    ∇b   = Σ_b err                             [10]
which `kernels/ref.py` cross-checks against jax.grad.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import elementwise, matmul, projection

IMG = 784
CLASSES = 10
PARAM_DIM = IMG * CLASSES + CLASSES  # 7850


def unpack(params):
    w = params[: IMG * CLASSES].reshape(CLASSES, IMG)
    b = params[IMG * CLASSES :]
    return w, b


def per_device_grads(params, images, labels_onehot):
    """params [d], images [M,B,784], labels [M,B,10] → grads [M, d].

    The per-device loop unrolls at trace time (M is static), producing one
    Pallas matmul per device for the backward einsum plus one shared
    forward matmul over all M·B rows.
    """
    m, b, _ = images.shape
    w, bias = unpack(params)
    x = images.reshape(m * b, IMG)
    logits = matmul.matmul(x, w.T) + bias  # [M·B, 10]
    probs = jax.nn.softmax(logits, axis=-1)
    err = (probs - labels_onehot.reshape(m * b, CLASSES)) / b  # [M·B, 10]
    grads = []
    for dev in range(m):
        e = err[dev * b : (dev + 1) * b]  # [B, 10]
        xm = x[dev * b : (dev + 1) * b]  # [B, 784]
        gw = matmul.matmul(e.T, xm)  # [10, 784]
        gb = jnp.sum(e, axis=0)  # [10]
        grads.append(jnp.concatenate([gw.reshape(-1), gb]))
    return jnp.stack(grads)


def project(a, g):
    """A-DSGD projection g̃ = A·g (L1 kernel)."""
    return projection.project(a, g)


def amp_step(a, y, x, r, threshold_mult):
    """One AMP iteration: (x, r) → (x', r', τ). Mirrors rust amp::recover."""
    s = a.shape[0]
    sigma = jnp.linalg.norm(r) / jnp.sqrt(jnp.asarray(s, jnp.float32))
    tau = threshold_mult * sigma
    # Pseudo-data u = x + Aᵀr via the matmul kernel (vecmat form).
    at_r = matmul.vecmat(r, a)
    pseudo = elementwise.axpby(1.0, x, 1.0, at_r)
    x_new = elementwise.soft_threshold(pseudo, tau)
    onsager = jnp.count_nonzero(x_new).astype(jnp.float32) / s
    ax = projection.project(a, x_new)
    # r' = (y − Ax') + b·r
    r_new = elementwise.axpby(1.0, y - ax, onsager, r)
    return x_new, r_new, tau
